"""Mixture-of-Experts FFN — sort-based (MegaBlocks-style) dispatch.

TPU-native choice (DESIGN.md §3/§4): instead of the GShard one-hot dispatch
einsum (whose (T, E, C) mask is ~10 GB at our 4k-train cell), tokens are
*sorted by expert id* and gathered into an (E, C, d) buffer — O(T·K) sort +
two gathers.  Capacity overflow drops tokens (standard).  Sharding:

  * ``expert_sharding='ep'``  — experts over the 'model' axis (llama4:
    128/16 = 8 per shard); GSPMD turns the gather/scatter into all-to-alls.
  * ``expert_sharding='tp'``  — expert count not divisible (qwen2-moe's
    60): shard each expert's d_ff over 'model' instead.

Shared experts (qwen2-moe: 4 merged into one wide SwiGLU; llama4: 1) are a
plain dense FFN added to the routed output.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ArchConfig


def moe_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 7)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": cm.dense_init(ks[0], d, E, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32)
                   / jnp.sqrt(d)).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32)
                 / jnp.sqrt(d)).astype(jnp.float32),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                   / jnp.sqrt(f)).astype(jnp.float32),
    }
    if cfg.shared_d_ff:
        p["shared"] = {
            "w_gate": cm.dense_init(ks[4], d, cfg.shared_d_ff),
            "w_up": cm.dense_init(ks[5], d, cfg.shared_d_ff),
            "w_down": cm.dense_init(ks[6], cfg.shared_d_ff, d),
        }
    return p


def _capacity(cfg: ArchConfig, n_assign: int) -> int:
    c = int(n_assign * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def _n_groups(cfg: ArchConfig, T: int) -> int:
    g = min(cfg.moe_groups, T)
    while T % g != 0:
        g -= 1
    return max(g, 1)


def _dispatch_group(cfg: ArchConfig, x, eids, gates, C: int):
    """Sort-based dispatch for ONE group.  x (Tg, d); eids/gates (Tg, K).
    Returns (xe (E, C, d), ts, slot, keep, gs) for the combine."""
    Tg, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    dt = x.dtype
    n_assign = Tg * K
    e_flat = eids.reshape(-1)
    g_flat = gates.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)
    perm = jnp.argsort(e_flat)
    es, ts, gs = e_flat[perm], t_flat[perm], g_flat[perm]
    counts = jax.ops.segment_sum(jnp.ones_like(es), es, num_segments=E)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(n_assign, dtype=jnp.int32) - offsets[es].astype(jnp.int32)
    keep = rank < C
    slot = jnp.where(keep, es * C + rank, E * C)     # overflow -> dump row
    xbuf = jnp.zeros((E * C + 1, d), dt).at[slot].set(x[ts])
    return xbuf[: E * C].reshape(E, C, d), ts, slot, keep, gs


def _combine_group(cfg: ArchConfig, ye, ts, slot, keep, gs, Tg: int):
    E = cfg.n_experts
    C = ye.shape[1]
    d = ye.shape[-1]
    dt = ye.dtype
    y_rows = ye.reshape(E * C, d)
    contrib = jnp.where(keep[:, None], y_rows[jnp.minimum(slot, E * C - 1)], 0.0)
    contrib = contrib * gs[:, None].astype(dt)
    return jnp.zeros((Tg, d), dt).at[ts].add(contrib)


def moe_apply(cfg: ArchConfig, p, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (T, d) tokens.  Returns (y (T, d), aux_loss ()).

    GROUPED dispatch (GShard-style): tokens are split into
    ``cfg.moe_groups`` groups aligned with the DP shards, and the
    sort/gather/scatter run *per group* (vmapped, leading axis sharded
    over ('pod','data')).  With a single global group the dispatch
    gathers index into the full (T, d) token buffer — GSPMD cannot prove
    locality and all-gathers ~10 GB/device at the 4k-train cells
    (measured; EXPERIMENTS.md §Perf).  Per-group capacity also matches
    how real MoE frameworks enforce it.  Expert weights stay sharded
    over 'model' (EP or per-expert TP); GSPMD inserts the all-to-all at
    the (G, E, C, d) buffer boundary."""
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, K)                        # (T, K)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss (global).
    me = jnp.mean(probs, axis=0)                                 # (E,)
    ce = jnp.mean(jax.nn.one_hot(eids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * E

    G = _n_groups(cfg, T)
    Tg = T // G
    C = _capacity(cfg, Tg * K)
    xg = _shard(x.reshape(G, Tg, d), (("pod", "data"), None, None))
    eg = eids.reshape(G, Tg, K)
    gg = gates.reshape(G, Tg, K)

    xe, ts, slot, keep, gs = jax.vmap(
        lambda xi, ei, gi: _dispatch_group(cfg, xi, ei, gi, C))(xg, eg, gg)
    if cfg.expert_sharding == "ep":
        xe = _shard(xe, (("pod", "data"), "model", None, None))
    else:
        xe = _shard(xe, (("pod", "data"), None, None, "model"))

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    # FSDP: pin the bf16 cast BEFORE the weight all-gather — otherwise
    # GSPMD gathers the f32 master shards and converts after (2x the
    # gather traffic and 2x the gathered-weight temps; §Perf llama4 L3)
    if cfg.expert_sharding == "ep":
        wspec = ("model", None, None)
        wdspec = wspec
    else:
        wspec = (None, None, "model")
        wdspec = (None, "model", None)
    # pin the bf16 cast's sharding so the FSDP all-gather moves bf16
    # weights, not the f32 master (EXPERIMENTS.md §Perf llama4 L3; the
    # stronger barrier variants L4/L4b were refuted and removed).  Only
    # worthwhile when enough tokens route to amortize the gather — decode
    # (T ≈ batch) skips it, keeping weights FSDP-sharded.
    pin = T >= 8 * E
    wg = _shard(p["w_gate"].astype(dt), wspec) if pin \
        else p["w_gate"].astype(dt)
    wu = _shard(p["w_up"].astype(dt), wspec) if pin \
        else p["w_up"].astype(dt)
    wd = _shard(p["w_down"].astype(dt), wdspec) if pin \
        else p["w_down"].astype(dt)
    h = act(jnp.einsum("gecd,edf->gecf", xe, wg)) * \
        jnp.einsum("gecd,edf->gecf", xe, wu)
    if cfg.expert_sharding == "tp":
        h = _shard(h, (("pod", "data"), None, None, "model"))
    ye = jnp.einsum("gecf,efd->gecd", h, wd)

    y = jax.vmap(
        lambda yi, t, s, k, g: _combine_group(cfg, yi, t, s, k, g, Tg))(
            ye, ts, slot, keep, gs)
    y = _shard(y, (("pod", "data"), None, None)).reshape(T, d)

    if cfg.shared_d_ff:
        sp = p["shared"]
        hs = act(x @ sp["w_gate"].astype(dt)) * (x @ sp["w_up"].astype(dt))
        hs = _shard(hs.reshape(G, Tg, -1), (("pod", "data"), None, "model"))
        y = y + (hs @ sp["w_down"].astype(dt)).reshape(T, d)
    return y, aux


def _shard(x, axes):
    """Best-effort sharding constraint — no-op outside a mesh context."""
    try:
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import constraint
        return constraint(x, P(*axes))
    except Exception:
        return x
