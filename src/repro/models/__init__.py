"""Model zoo: param-dict pure-function models, scan-over-layers stacks."""
