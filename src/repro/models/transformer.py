"""Decoder-only LM family: dense GQA (internlm2 / yi / granite / qwen2) and
MoE variants (qwen2-moe / llama4-maverick).  Also the text backbone reused
by the VLM.

All stacks are a single ``lax.scan`` over stacked layer params; training
wraps the body in ``jax.checkpoint`` (remat).  ``moe_every == 2``
(llama4-maverick) interleaves dense-FFN and MoE layers: the scan unit
becomes a [dense, moe] *block* so the stack stays a single homogeneous
scan (compile economy, DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import moe as moe_lib
from repro.models.config import ArchConfig

Params = Dict[str, Any]


def uses_blocks(cfg: ArchConfig) -> bool:
    return cfg.family == "moe" and cfg.moe_every > 1


def _dense_cfg(cfg: ArchConfig) -> ArchConfig:
    """The interleaved dense layer's view of the config."""
    return dataclasses.replace(cfg, family="gqa",
                               d_ff=cfg.dense_d_ff or cfg.d_ff)


def n_scan_units(cfg: ArchConfig) -> int:
    if uses_blocks(cfg):
        assert cfg.moe_every == 2, "only moe_every in (1, 2) is implemented"
        assert cfg.n_layers % 2 == 0
        return cfg.n_layers // 2
    return cfg.n_layers


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def _ffn_init(key, cfg: ArchConfig):
    if cfg.family == "moe":
        return moe_lib.moe_init(key, cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": cm.dense_init(k1, cfg.d_model, cfg.d_ff),
            "w_up": cm.dense_init(k2, cfg.d_model, cfg.d_ff),
            "w_down": cm.dense_init(k3, cfg.d_ff, cfg.d_model)}


def _ffn_apply(cfg: ArchConfig, p, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (y, aux_loss)."""
    if cfg.family == "moe":
        b, s, d = x.shape
        y, aux = moe_lib.moe_apply(cfg, p, x.reshape(b * s, d))
        return y.reshape(b, s, d), aux
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    dt = x.dtype
    h = act(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    h = cm.shard_act(h, None, "model")
    return h @ p["w_down"].astype(dt), jnp.zeros((), jnp.float32)


def layer_init(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                               cfg.head_dim, cfg.qkv_bias),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "ffn": _ffn_init(k2, cfg),
    }


def _attn_mode(cfg: ArchConfig) -> str:
    """'heads' (TP over heads) or 'qseq' (q-sequence sharding fallback)."""
    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    model = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1) \
        if mesh is not None else 1
    heads = cfg.n_heads if not cfg.repeat_kv else cfg.n_heads
    return "heads" if heads % model == 0 else "qseq"


def _sharded_attention(cfg: ArchConfig, q, k, v):
    """TP-constrained flash attention.

    * ``cfg.repeat_kv``: KV heads replicated up to hq (Megatron GQA-on-TP
      — hq divides the model axis but hkv doesn't), einsums head-local.
    * heads divide the model axis → shard the head dim;
    * otherwise (qwen2's 14H, llama4's 40H) → shard the *q-sequence* dim
      over 'model' and replicate K/V: causal attention is independent per
      query position, so scores shrink by the TP degree instead of being
      replicated at full head count (a 10.7 GiB/chunk f32 buffer at the
      llama4 train cell — measured, EXPERIMENTS.md §Perf)."""
    if cfg.repeat_kv and cfg.n_heads != cfg.n_kv:
        g = cfg.n_heads // cfg.n_kv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if _attn_mode(cfg) == "heads":
        q = cm.shard_act(q, None, "model", None)
        k = cm.shard_act(k, None, "model", None)
        v = cm.shard_act(v, None, "model", None)
        o = attn.flash_attention(q, k, v, True, cfg.attn_chunk)
        return cm.shard_act(o, None, "model", None)
    q = cm.shard_act(q, "model", None, None)
    k = cm.shard_act(k, None, None, None)
    v = cm.shard_act(v, None, None, None)
    o = attn.flash_attention(q, k, v, True, cfg.attn_chunk)
    return cm.shard_act(o, "model", None, None)


def layer_apply_train(cfg: ArchConfig, p, x: jnp.ndarray,
                      positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    # gather boundary pinned at the bf16 post-norm tensor — but ONLY for
    # head-sharded attention; q-seq-sharded archs (llama4/qwen2) keep the
    # residual seq-sharded straight into the q projection (§Perf L2)
    h = cm.rmsnorm(x, p["ln1"])
    h = cm.shard_act(h, None, None) if _attn_mode(cfg) == "heads" \
        else cm.shard_act(h, "model", None)
    q, k, v = attn.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv, cfg.head_dim)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    o = _sharded_attention(cfg, q, k, v)
    # row-parallel outputs constrained seq-sharded BEFORE the residual
    # add => reduce-scatter instead of all-reduce (§Perf)
    x = x + cm.shard_act(attn.attn_out(p["attn"], o), "model", None)
    h = cm.shard_act(cm.rmsnorm(x, p["ln2"]), None, None)
    f, aux = _ffn_apply(cfg, p["ffn"], h)
    return x + cm.shard_act(f, "model", None), aux


def layer_prefill(cfg: ArchConfig, p, x: jnp.ndarray, positions: jnp.ndarray):
    """Like train but returns the (k, v) cache for this layer."""
    h = cm.rmsnorm(x, p["ln1"])
    h = cm.shard_act(h, None, None) if _attn_mode(cfg) == "heads" \
        else cm.shard_act(h, "model", None)
    q, k, v = attn.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv, cfg.head_dim)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    o = _sharded_attention(cfg, q, k, v)
    x = x + cm.shard_act(attn.attn_out(p["attn"], o), "model", None)
    h = cm.shard_act(cm.rmsnorm(x, p["ln2"]), None, None)
    f, _ = _ffn_apply(cfg, p["ffn"], h)
    return x + cm.shard_act(f, "model", None), (k, v)


def layer_decode(cfg: ArchConfig, p, x: jnp.ndarray, ck: jnp.ndarray,
                 cv: jnp.ndarray, pos: jnp.ndarray):
    """x (b,1,d); ck/cv (b,S,hkv,hd); pos () current length."""
    h = cm.rmsnorm(x, p["ln1"])
    q, k, v = attn.attn_qkv(p["attn"], h, cfg.n_heads, cfg.n_kv, cfg.head_dim)
    posv = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q = cm.apply_rope(q, posv, cfg.rope_theta)
    k = cm.apply_rope(k, posv, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, pos, 0, 0))
    o = attn.decode_attention(q, ck, cv, pos + 1)
    x = x + attn.attn_out(p["attn"], o)
    h = cm.rmsnorm(x, p["ln2"])
    f, _ = _ffn_apply(cfg, p["ffn"], h)
    return x + f, ck, cv


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig) -> Params:
    ke, kl, kh = jax.random.split(key, 3)
    if uses_blocks(cfg):
        nb = n_scan_units(cfg)
        ka, kb = jax.random.split(kl)
        dcfg = _dense_cfg(cfg)
        layers = {
            "dense": jax.vmap(lambda k: layer_init(k, dcfg))(
                jax.random.split(ka, nb)),
            "moe": jax.vmap(lambda k: layer_init(k, cfg))(
                jax.random.split(kb, nb)),
        }
    else:
        layers = jax.vmap(lambda k: layer_init(k, cfg))(
            jax.random.split(kl, cfg.n_layers))
    p = {
        "tok_embed": {"table": cm.embed_init(ke, cfg.vocab, cfg.d_model)},
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": {"table": cm.embed_init(kh, cfg.vocab, cfg.d_model)},
    }
    return p


def backbone_train(cfg: ArchConfig, params: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, remat: bool = True):
    """Run the layer stack; x (b,s,d).  Returns (x, total_aux_loss)."""
    blocks = uses_blocks(cfg)
    dcfg = _dense_cfg(cfg) if blocks else None

    def body(carry, lp):
        h, aux = carry
        if blocks:
            h, a1 = layer_apply_train(dcfg, lp["dense"], h, positions)
            h, a2 = layer_apply_train(cfg, lp["moe"], h, positions)
            a = a1 + a2
        else:
            h, a = layer_apply_train(cfg, lp, h, positions)
        return (h, aux + a), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return x, aux


def embed(cfg: ArchConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["tok_embed"]["table"].astype(cfg.dtype)[tokens]


def logits_fn(cfg: ArchConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = cm.rmsnorm(x, params["final_norm"])
    table = params["lm_head"]["table"].astype(cfg.dtype)
    return x @ table.T


def train_loss(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray],
               *, remat: bool = True, sampled_softmax: bool = False) -> jnp.ndarray:
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x = cm.shard_act(embed(cfg, params, tokens), "model", None)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, aux = backbone_train(cfg, params, x, positions, remat=remat)
    x = cm.rmsnorm(x, params["final_norm"])
    if sampled_softmax:
        loss = cm.sampled_softmax_xent(
            x.reshape(b * s, -1), params["lm_head"]["table"],
            labels.reshape(-1), batch["neg_ids"])
    else:
        loss = cm.chunked_softmax_xent(
            x, params["lm_head"]["table"], labels, cfg.loss_chunk)
    return loss + 0.01 * aux


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.dtype
    sub = (2,) if uses_blocks(cfg) else ()
    shape = (n_scan_units(cfg),) + sub + (batch, max_seq, cfg.n_kv,
                                          cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((), jnp.int32)}


def prefill(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            max_seq: Optional[int] = None):
    """Returns (last-position logits (b, vocab), cache)."""
    b, s = tokens.shape
    max_seq = max_seq or s
    x = embed(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    blocks = uses_blocks(cfg)
    dcfg = _dense_cfg(cfg) if blocks else None

    def body(h, lp):
        if blocks:
            h, (k1, v1) = layer_prefill(dcfg, lp["dense"], h, positions)
            h, (k2, v2) = layer_prefill(cfg, lp["moe"], h, positions)
            k = jnp.stack([k1, k2])
            v = jnp.stack([v1, v2])
        else:
            h, (k, v) = layer_prefill(cfg, lp, h, positions)
        return h, (k.astype(cfg.dtype), v.astype(cfg.dtype))

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    if max_seq > s:
        pad = [(0, 0)] * (ks.ndim - 3) + [(0, max_seq - s), (0, 0), (0, 0)]
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    logits = logits_fn(cfg, params, x[:, -1:])[:, 0]
    cache = {"k": ks, "v": vs, "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(cfg: ArchConfig, params: Params, cache, token: jnp.ndarray):
    """token (b,) int32.  Returns (logits (b, vocab), cache')."""
    b = token.shape[0]
    x = embed(cfg, params, token[:, None])
    pos = cache["len"]
    blocks = uses_blocks(cfg)
    dcfg = _dense_cfg(cfg) if blocks else None

    def body(h, xs):
        lp, ck, cv = xs
        if blocks:
            h, ck1, cv1 = layer_decode(dcfg, lp["dense"], h, ck[0], cv[0], pos)
            h, ck2, cv2 = layer_decode(cfg, lp["moe"], h, ck[1], cv[1], pos)
            ck = jnp.stack([ck1, ck2])
            cv = jnp.stack([cv1, cv2])
        else:
            h, ck, cv = layer_decode(cfg, lp, h, ck, cv, pos)
        return h, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    logits = logits_fn(cfg, params, x)[:, 0]
    return logits, {"k": ks, "v": vs, "len": pos + 1}
