"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings (b, enc_seq, d_model) directly to the
encoder.  Encoder: bidirectional self-attention + GELU MLP, LayerNorm
(with bias) as in Whisper.  Decoder: causal self-attn, cross-attn to the
encoder states, MLP.  Sinusoidal absolute positions (no RoPE).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models.config import ArchConfig


def _ln_init(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def _ln(x, p):
    return cm.layernorm(x, p["scale"], p["bias"])


def _mlp_init(key, d, f):
    k1, k2 = jax.random.split(key)
    return {"w1": cm.dense_init(k1, d, f), "w2": cm.dense_init(k2, f, d)}


def _mlp(p, x):
    return jax.nn.gelu(x @ p["w1"].astype(x.dtype)) @ p["w2"].astype(x.dtype)


def enc_layer_init(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": _ln_init(cfg.d_model),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                   cfg.head_dim),
            "ln2": _ln_init(cfg.d_model),
            "mlp": _mlp_init(k2, cfg.d_model, cfg.d_ff)}


def dec_layer_init(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": _ln_init(cfg.d_model),
            "self_attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv, cfg.head_dim),
            "ln2": _ln_init(cfg.d_model),
            "cross_attn": attn.attn_init(k2, cfg.d_model, cfg.n_heads,
                                         cfg.n_heads, cfg.head_dim),
            "ln3": _ln_init(cfg.d_model),
            "mlp": _mlp_init(k3, cfg.d_model, cfg.d_ff)}


def init(key, cfg: ArchConfig):
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: enc_layer_init(k, cfg))(
        jax.random.split(ke, cfg.enc_layers))
    dec = jax.vmap(lambda k: dec_layer_init(k, cfg))(
        jax.random.split(kd, cfg.n_layers))
    return {"enc_layers": enc,
            "enc_norm": _ln_init(cfg.d_model),
            "tok_embed": {"table": cm.embed_init(kt, cfg.vocab, cfg.d_model)},
            "dec_layers": dec,
            "final_norm": _ln_init(cfg.d_model),
            "lm_head": {"table": cm.embed_init(kh, cfg.vocab, cfg.d_model)}}


def encode(cfg: ArchConfig, params, frames: jnp.ndarray,
           remat: bool = False) -> jnp.ndarray:
    """frames: (b, enc_seq, d_model) stub embeddings."""
    b, s, d = frames.shape
    x = frames.astype(cfg.dtype) + cm.sinusoidal_positions(s, d).astype(cfg.dtype)
    x = cm.shard_act(x, None, None)

    def body(h, lp):
        a = _ln(h, lp["ln1"])
        q, k, v = attn.attn_qkv(lp["attn"], a, cfg.n_heads, cfg.n_kv,
                                cfg.head_dim)
        q = cm.shard_act(q, None, "model", None)
        k = cm.shard_act(k, None, "model", None)
        v = cm.shard_act(v, None, "model", None)
        h = h + attn.attn_out(lp["attn"],
                              attn.flash_attention(q, k, v, False, cfg.attn_chunk))
        h = h + _mlp(lp["mlp"], _ln(h, lp["ln2"]))
        return cm.shard_act(h, None, None), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(x, params["enc_norm"])


def _dec_layer_full(cfg, lp, x, enc_out, positions, return_cache=False):
    """Training / prefill decoder layer (full sequence)."""
    h = _ln(x, lp["ln1"])
    q, k, v = attn.attn_qkv(lp["self_attn"], h, cfg.n_heads, cfg.n_kv,
                            cfg.head_dim)
    x = x + attn.attn_out(lp["self_attn"],
                          attn.flash_attention(q, k, v, True, cfg.attn_chunk))
    h = _ln(x, lp["ln2"])
    cq, ck, cv = attn.attn_qkv(lp["cross_attn"], h, cfg.n_heads, cfg.n_heads,
                               cfg.head_dim)
    # cross K/V come from the encoder output instead
    b, se, _ = enc_out.shape
    ck = (enc_out @ lp["cross_attn"]["wk"].astype(enc_out.dtype)).reshape(
        b, se, cfg.n_heads, cfg.head_dim)
    cv = (enc_out @ lp["cross_attn"]["wv"].astype(enc_out.dtype)).reshape(
        b, se, cfg.n_heads, cfg.head_dim)
    x = x + attn.attn_out(lp["cross_attn"],
                          attn.flash_attention(cq, ck, cv, False, cfg.attn_chunk))
    x = x + _mlp(lp["mlp"], _ln(x, lp["ln3"]))
    if return_cache:
        return x, (k, v, ck, cv)
    return x


def train_loss(cfg: ArchConfig, params, batch, *, remat: bool = True,
               sampled_softmax: bool = False):
    """batch: frames (b, enc_seq, d), tokens (b,s), labels (b,s)."""
    frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
    enc_out = encode(cfg, params, frames, remat=remat)
    b, s = tokens.shape
    x = params["tok_embed"]["table"].astype(cfg.dtype)[tokens]
    x = x + cm.sinusoidal_positions(s, cfg.d_model).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(h, lp):
        return _dec_layer_full(cfg, lp, h, enc_out, positions), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(x, params["final_norm"])
    if sampled_softmax:
        return cm.sampled_softmax_xent(x.reshape(b * s, -1),
                                       params["lm_head"]["table"],
                                       labels.reshape(-1), batch["neg_ids"])
    return cm.chunked_softmax_xent(
        x, params["lm_head"]["table"], labels, cfg.loss_chunk)


def prefill(cfg: ArchConfig, params, frames: jnp.ndarray,
            tokens: jnp.ndarray, max_seq=None):
    """Returns (last logits, cache).  cache: self-KV + cross-KV per layer."""
    enc_out = encode(cfg, params, frames)
    b, s = tokens.shape
    max_seq = max_seq or s
    x = params["tok_embed"]["table"].astype(cfg.dtype)[tokens]
    x = x + cm.sinusoidal_positions(s, cfg.d_model).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(h, lp):
        h, (k, v, ck, cv) = _dec_layer_full(cfg, lp, h, enc_out, positions,
                                            return_cache=True)
        return h, (k.astype(cfg.dtype), v.astype(cfg.dtype),
                   ck.astype(cfg.dtype), cv.astype(cfg.dtype))

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"])
    if max_seq > s:
        pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    x = _ln(x[:, -1:], params["final_norm"])
    logits = (x @ params["lm_head"]["table"].astype(cfg.dtype).T)[:, 0]
    return logits, {"k": ks, "v": vs, "ck": cks, "cv": cvs,
                    "len": jnp.asarray(s, jnp.int32)}


def decode_step(cfg: ArchConfig, params, cache, token: jnp.ndarray):
    b = token.shape[0]
    pos = cache["len"]
    x = params["tok_embed"]["table"].astype(cfg.dtype)[token[:, None]]
    pe = cm.sinusoidal_positions(8192, cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(pe, pos, 1, 0)[None].astype(cfg.dtype)

    def body(h, xs):
        lp, ck_s, cv_s, ckx, cvx = xs
        a = _ln(h, lp["ln1"])
        q, k, v = attn.attn_qkv(lp["self_attn"], a, cfg.n_heads, cfg.n_kv,
                                cfg.head_dim)
        ck_s = jax.lax.dynamic_update_slice(ck_s, k.astype(ck_s.dtype),
                                            (0, pos, 0, 0))
        cv_s = jax.lax.dynamic_update_slice(cv_s, v.astype(cv_s.dtype),
                                            (0, pos, 0, 0))
        h = h + attn.attn_out(lp["self_attn"],
                              attn.decode_attention(q, ck_s, cv_s, pos + 1))
        a = _ln(h, lp["ln2"])
        cq, _, _ = attn.attn_qkv(lp["cross_attn"], a, cfg.n_heads,
                                 cfg.n_heads, cfg.head_dim)
        h = h + attn.attn_out(
            lp["cross_attn"],
            attn.decode_attention(cq, ckx, cvx, ckx.shape[1]))
        h = h + _mlp(lp["mlp"], _ln(h, lp["ln3"]))
        return h, (ck_s, cv_s)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    x = _ln(x, params["final_norm"])
    logits = (x @ params["lm_head"]["table"].astype(cfg.dtype).T)[:, 0]
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"],
                    "len": pos + 1}
