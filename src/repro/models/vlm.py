"""InternVL2-style VLM: stubbed ViT frontend + InternLM2 text backbone.

Per the assignment the vision tower is a STUB — ``input_specs()`` provides
precomputed patch embeddings (b, n_patches, d_model), already projected to
the language model width.  The backbone is the same GQA decoder as
internlm2; the multimodal part is prefix-concatenation ([vision; text])
with loss computed on text positions only.  Decode reuses the transformer
KV-cache path unchanged (vision lives in the prefix cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import transformer as tf
from repro.models.config import ArchConfig


def init(key, cfg: ArchConfig):
    return tf.init(key, cfg)


def train_loss(cfg: ArchConfig, params, batch, *, remat: bool = True,
               sampled_softmax: bool = False):
    """batch: patches (b, P, d_model), tokens (b, s), labels (b, s)."""
    patches, tokens, labels = batch["patches"], batch["tokens"], batch["labels"]
    b, P, _ = patches.shape
    s = tokens.shape[1]
    x_txt = tf.embed(cfg, params, tokens)
    x = jnp.concatenate([patches.astype(cfg.dtype), x_txt], axis=1)
    positions = jnp.broadcast_to(jnp.arange(P + s), (b, P + s))
    x, aux = tf.backbone_train(cfg, params, x, positions, remat=remat)
    x = cm.rmsnorm(x[:, P:], params["final_norm"])   # text positions only
    if sampled_softmax:
        loss = cm.sampled_softmax_xent(x.reshape(b * s, -1),
                                       params["lm_head"]["table"],
                                       labels.reshape(-1), batch["neg_ids"])
    else:
        loss = cm.chunked_softmax_xent(
            x, params["lm_head"]["table"], labels, cfg.loss_chunk)
    return loss + 0.01 * aux


def prefill(cfg: ArchConfig, params, patches: jnp.ndarray,
            tokens: jnp.ndarray, max_seq=None):
    """Prefix = [vision; text]; returns (last logits, transformer cache)."""
    b, P, _ = patches.shape
    s = tokens.shape[1]
    total = P + s
    max_seq = max_seq or total
    x = jnp.concatenate([patches.astype(cfg.dtype),
                         tf.embed(cfg, params, tokens)], axis=1)
    positions = jnp.broadcast_to(jnp.arange(total), (b, total))

    def body(h, lp):
        h, (k, v) = tf.layer_prefill(cfg, lp, h, positions)
        return h, (k.astype(cfg.dtype), v.astype(cfg.dtype))

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    if max_seq > total:
        pad = [(0, 0), (0, 0), (0, max_seq - total), (0, 0), (0, 0)]
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    logits = tf.logits_fn(cfg, params, x[:, -1:])[:, 0]
    return logits, {"k": ks, "v": vs, "len": jnp.asarray(total, jnp.int32)}


def decode_step(cfg: ArchConfig, params, cache, token: jnp.ndarray):
    return tf.decode_step(cfg, params, cache, token)
