"""RWKV-6 ("Finch") — attention-free, data-dependent per-channel decay.

Core recurrence per head (k-dim K, v-dim V, state S ∈ R^{K×V}):

    wkv_t = (diag(u)·k_t)·v_tᵀ + S_t
    out_t = r_tᵀ · wkv_t
    S_{t+1} = diag(w_t)·S_t + k_t·v_tᵀ          w_t = exp(−exp(x·lora))

Training/prefill use the GLA-style *chunked* form (chunk = cfg.rwkv_chunk):
within a chunk, pairwise decays factor into
``(r ⊙ exp(lwX)) @ (k ⊙ exp(−lwI))ᵀ`` where lwX/lwI are the exclusive /
inclusive cumulative log-decays — all matmuls (MXU-friendly), no (L,L,K)
tensor.  Log-decays are clipped to [−CLIP, −1e−6] so the e^{+lwI} factor
stays in fp32 range for the chunk length (CLIP·chunk ≤ 64).  Decode is the
exact recurrence (one step).  ``wkv_scan`` is the sequential oracle used
by tests.

Simplifications vs the released model (noted per DESIGN.md §8): static
token-shift lerp (v5-style) except for the decay, which keeps the v6
data-dependent LoRA; single-layernorm head groups.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ArchConfig

LOG_DECAY_CLIP = 4.0


# ---------------------------------------------------------------------------
# wkv core
# ---------------------------------------------------------------------------

def wkv_scan(r, k, v, logw, u, S0):
    """Sequential oracle.  r,k,v,logw: (b, s, h, K|V); u: (h, K);
    S0: (b, h, K, V).  Returns (out (b,s,h,V), S_final)."""

    def step(S, xs):
        r_t, k_t, v_t, lw_t = xs                      # (b,h,K),(b,h,K),(b,h,V),(b,h,K)
        kv = k_t[..., :, None] * v_t[..., None, :]    # (b,h,K,V)
        wkv = u[None, :, :, None] * kv + S
        out = jnp.einsum("bhk,bhkv->bhv", r_t, wkv)
        S = jnp.exp(lw_t)[..., None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, logw))
    S, out = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(out, 0, 1), S


def wkv_chunked(r, k, v, logw, u, S0, chunk: int):
    """Chunked parallel form.  Shapes as in ``wkv_scan``."""
    b, s, h, K = r.shape
    V = v.shape[-1]
    if s % chunk != 0:
        return wkv_scan(r, k, v, logw, u, S0)
    n = s // chunk
    rc, kc, vc, lwc = (x.reshape(b, n, chunk, h, -1) for x in (r, k, v, logw))

    def per_chunk(S, xs):
        rb, kb, vb, lwb = xs                          # (b, L, h, *)
        lwI = jnp.cumsum(lwb, axis=1)                 # inclusive (b,L,h,K)
        lwX = lwI - lwb                               # exclusive
        r_dec = rb * jnp.exp(lwX)
        k_inv = kb * jnp.exp(-lwI)
        # intra-chunk pairwise (strictly causal τ < i)
        scores = jnp.einsum("bihk,bjhk->bhij", r_dec, k_inv)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        out = jnp.einsum("bhij,bjhv->bihv", scores, vb)
        # current-token bonus
        out = out + jnp.einsum("bihk,bihv->bihv",
                               rb * u[None, None] * kb, vb)
        # inter-chunk state contribution
        out = out + jnp.einsum("bihk,bhkv->bihv", r_dec, S)
        # state update
        lw_tot = lwI[:, -1]                           # (b,h,K)
        k_dec = kb * jnp.exp(lw_tot[:, None] - lwI)
        S = jnp.exp(lw_tot)[..., None] * S + \
            jnp.einsum("bjhk,bjhv->bhkv", k_dec, vb)
        return S, out

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rc, kc, vc, lwc))
    S, out = jax.lax.scan(per_chunk, S0, xs)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, V)
    return out, S


def wkv_step(r, k, v, logw, u, S):
    """One decode step.  r,k,v,logw (b,h,*); S (b,h,K,V)."""
    kv = k[..., :, None] * v[..., None, :]
    wkv = u[None, :, :, None] * kv + S
    out = jnp.einsum("bhk,bhkv->bhv", r, wkv)
    S = jnp.exp(logw)[..., None] * S + kv
    return out, S


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    h = cfg.rwkv_heads
    ks = jax.random.split(key, 12)
    lora = max(32, d // 64)
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "tm": {
            "mix_r": jnp.full((d,), 0.5, jnp.float32),
            "mix_k": jnp.full((d,), 0.5, jnp.float32),
            "mix_v": jnp.full((d,), 0.5, jnp.float32),
            "mix_g": jnp.full((d,), 0.5, jnp.float32),
            "mix_w": jnp.full((d,), 0.5, jnp.float32),
            "wr": cm.dense_init(ks[0], d, d),
            "wk": cm.dense_init(ks[1], d, d),
            "wv": cm.dense_init(ks[2], d, d),
            "wg": cm.dense_init(ks[3], d, d),
            "wo": cm.dense_init(ks[4], d, d),
            # v6 data-dependent decay LoRA: w = base + tanh(x A) B
            "w_base": jnp.full((d,), -2.0, jnp.float32),
            "w_A": cm.dense_init(ks[5], d, lora, scale=0.01),
            "w_B": cm.dense_init(ks[6], lora, d, scale=0.01),
            "u": (jax.random.normal(ks[7], (h, hd), jnp.float32) * 0.1),
            "gn": jnp.ones((d,), jnp.float32),
        },
        "cm": {
            "mix_k": jnp.full((d,), 0.5, jnp.float32),
            "mix_r": jnp.full((d,), 0.5, jnp.float32),
            "wk": cm.dense_init(ks[8], d, f),
            "wv": cm.dense_init(ks[9], f, d),
            "wr": cm.dense_init(ks[10], d, d),
        },
    }


def _shift(x: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """Token shift: x_{t-1}; position 0 takes ``prev`` (carry or zeros)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def time_mix(cfg: ArchConfig, p, x: jnp.ndarray, x_prev: jnp.ndarray,
             S0: jnp.ndarray, mode: str):
    """x (b,s,d); x_prev (b,d) carry; S0 (b,h,K,V).  Returns (out, x_last, S)."""
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    dt = x.dtype
    xs = _shift(x, x_prev)

    def lerp(mix):
        return x + mix.astype(dt) * (xs - x)

    r = (lerp(p["mix_r"]) @ p["wr"].astype(dt)).reshape(b, s, h, hd)
    k = (lerp(p["mix_k"]) @ p["wk"].astype(dt)).reshape(b, s, h, hd)
    v = (lerp(p["mix_v"]) @ p["wv"].astype(dt)).reshape(b, s, h, hd)
    g = lerp(p["mix_g"]) @ p["wg"].astype(dt)
    xw = lerp(p["mix_w"]).astype(jnp.float32)
    dd = jnp.tanh(xw @ p["w_A"]) @ p["w_B"]
    logw = -jnp.exp(p["w_base"][None, None] + dd)       # (b,s,d) < 0
    logw = jnp.clip(logw, -LOG_DECAY_CLIP, -1e-6).reshape(b, s, h, hd)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if mode == "chunked":
        out, S = wkv_chunked(rf, kf, vf, logw, p["u"], S0, cfg.rwkv_chunk)
    else:
        out, S = wkv_scan(rf, kf, vf, logw, p["u"], S0)
    out = out.reshape(b, s, d)
    out = cm.rmsnorm(out, p["gn"])                      # head-group norm
    out = (out * jax.nn.silu(g.astype(jnp.float32))).astype(dt)
    return out @ p["wo"].astype(dt), x[:, -1], S


def channel_mix(cfg: ArchConfig, p, x: jnp.ndarray, x_prev: jnp.ndarray):
    dt = x.dtype
    xs = _shift(x, x_prev)
    xk = x + p["mix_k"].astype(dt) * (xs - x)
    xr = x + p["mix_r"].astype(dt) * (xs - x)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    return jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * (kk @ p["wv"].astype(dt)), x[:, -1]


def layer_apply(cfg: ArchConfig, p, x: jnp.ndarray, state, mode: str):
    """state: dict(tm_x (b,d), cm_x (b,d), S (b,h,K,V)). Returns (x', state')."""
    h = cm.rmsnorm(x, p["ln1"])
    o, tm_x, S = time_mix(cfg, p["tm"], h, state["tm_x"].astype(h.dtype),
                          state["S"], mode)
    x = x + o
    h = cm.rmsnorm(x, p["ln2"])
    o, cm_x = channel_mix(cfg, p["cm"], h, state["cm_x"].astype(h.dtype))
    x = x + o
    return x, {"tm_x": tm_x.astype(jnp.float32), "cm_x": cm_x.astype(jnp.float32),
               "S": S}


def zero_state(cfg: ArchConfig, batch: int):
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "tm_x": jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.float32),
        "cm_x": jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.float32),
        "S": jnp.zeros((cfg.n_layers, batch, h, hd, hd), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig):
    ke, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: layer_init(k, cfg))(jax.random.split(kl, cfg.n_layers))
    return {"tok_embed": {"table": cm.embed_init(ke, cfg.vocab, cfg.d_model)},
            "layers": layers,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "lm_head": {"table": cm.embed_init(kh, cfg.vocab, cfg.d_model)}}


def _run_stack(cfg, params, x, state, mode, remat=False):
    def body(carry, xs):
        h = carry
        lp, st = xs
        h, st = layer_apply(cfg, lp, h, st, mode)
        return h, st

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, states = jax.lax.scan(body, x, (params["layers"], state))
    return x, states


def train_loss(cfg: ArchConfig, params, batch, *, remat: bool = True,
               sampled_softmax: bool = False):
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x = params["tok_embed"]["table"].astype(cfg.dtype)[tokens]
    state = zero_state(cfg, b)
    x, _ = _run_stack(cfg, params, x, state, "chunked", remat=remat)
    x = cm.rmsnorm(x, params["final_norm"])
    if sampled_softmax:
        return cm.sampled_softmax_xent(x.reshape(b * s, -1),
                                       params["lm_head"]["table"],
                                       labels.reshape(-1), batch["neg_ids"])
    return cm.chunked_softmax_xent(
        x, params["lm_head"]["table"], labels, cfg.loss_chunk)


def prefill(cfg: ArchConfig, params, tokens: jnp.ndarray, max_seq=None):
    b, s = tokens.shape
    x = params["tok_embed"]["table"].astype(cfg.dtype)[tokens]
    state = zero_state(cfg, b)
    x, state = _run_stack(cfg, params, x, state, "chunked")
    x = cm.rmsnorm(x[:, -1:], params["final_norm"])
    logits = (x @ params["lm_head"]["table"].astype(cfg.dtype).T)[:, 0]
    return logits, state


def decode_step(cfg: ArchConfig, params, state, token: jnp.ndarray):
    b = token.shape[0]
    x = params["tok_embed"]["table"].astype(cfg.dtype)[token[:, None]]

    def body(h, xs):
        lp, st = xs
        h, st = layer_apply(cfg, lp, h, st, "scan")
        return h, st

    x, state = jax.lax.scan(body, x, (params["layers"], state))
    x = cm.rmsnorm(x, params["final_norm"])
    logits = (x @ params["lm_head"]["table"].astype(cfg.dtype).T)[:, 0]
    return logits, state
