"""End-to-end driver: train a ~100M-parameter LM with the count-sketch
optimizer and compare its optimizer-state footprint against dense Adam.

    PYTHONPATH=src python examples/train_lm_100m.py --steps 200
    PYTHONPATH=src python examples/train_lm_100m.py --small   # CI-speed

The default config is ≈100 M params (vocab 50k × d 512 embedding+softmax
= 51 M, 8-layer body ≈ 50 M) — a few hundred CPU steps take ~10 min; on
a v5e slice the same script runs unchanged via repro.launch.train.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import optimizers as O
from repro.core.partition import SketchPolicy
from repro.core.stores import CountMinStore, CountSketchStore
from repro.core.transforms import chain, scale_by_adam, scale_by_lr, \
    scale_by_rmsprop
from repro.data import ZipfLM, ZipfLMConfig
from repro.models import transformer as tf
from repro.models.config import ArchConfig


def build_cfg(small: bool) -> ArchConfig:
    if small:
        return ArchConfig(name="lm-7m", family="gqa", n_layers=2,
                          d_model=128, n_heads=4, n_kv=2, head_dim=32,
                          d_ff=512, vocab_size=8192, vocab_multiple=64,
                          attn_chunk=64, loss_chunk=64,
                          compute_dtype="float32")
    return ArchConfig(name="lm-100m", family="gqa", n_layers=8,
                      d_model=512, n_heads=8, n_kv=4, head_dim=64,
                      d_ff=2048, vocab_size=50_048, vocab_multiple=64,
                      attn_chunk=128, loss_chunk=128,
                      compute_dtype="float32")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--optimizer", default="cs_adam",
                    choices=["cs_adam", "dense_adam", "cs_rmsprop"])
    args = ap.parse_args()

    cfg = build_cfg(args.small)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name}  {n_params / 1e6:.1f}M params")

    # the composable store/transform API (DESIGN.md §12): the Adam rule,
    # parameterized by where its moments live, chained with the lr scale
    policy = SketchPolicy(min_rows=1024)
    m_store = CountSketchStore(compression=5.0)   # signed, median query
    v_store = CountMinStore(compression=5.0)      # unsigned, min query
    opt = {"cs_adam": chain(scale_by_adam(m_store=m_store, v_store=v_store,
                                          where=policy),
                            scale_by_lr(1e-3)),
           "cs_rmsprop": chain(scale_by_rmsprop(v_store=v_store,
                                                where=policy),
                               scale_by_lr(1e-3)),
           "dense_adam": O.adam(1e-3)}[args.optimizer]
    st = opt.init(params)
    dense_bytes = O.state_bytes(O.adam(1e-3).init(params))
    print(f"optimizer: {args.optimizer}  state "
          f"{O.state_bytes(st) / 2**20:.1f} MiB "
          f"(dense Adam: {dense_bytes / 2**20:.1f} MiB)")

    data = ZipfLM(ZipfLMConfig(vocab_size=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch, alpha=1.1))

    @jax.jit
    def step(params, st, tokens, labels):
        def loss_fn(p):
            return tf.train_loss(cfg, p, {"tokens": tokens,
                                          "labels": labels}, remat=False)
        l, g = jax.value_and_grad(loss_fn)(params)
        g = O.clip_by_global_norm(1.0)(g)
        u, st = opt.update(g, st, params)
        return O.apply_updates(params, u), st, l

    losses = []
    t0 = time.perf_counter()
    for i in range(args.steps):
        b = data.batch(i)
        params, st, l = step(params, st, jnp.asarray(b["tokens"]),
                             jnp.asarray(b["labels"]))
        losses.append(float(l))
        if (i + 1) % 20 == 0:
            dt = (time.perf_counter() - t0) / (i + 1)
            print(f"step {i + 1:4d}  loss {np.mean(losses[-20:]):.3f}  "
                  f"ppl {np.exp(np.mean(losses[-20:])):8.1f}  "
                  f"{dt:.2f}s/step", flush=True)
    print(f"\nfinal: loss {np.mean(losses[-20:]):.3f} "
          f"(from {np.mean(losses[:10]):.3f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
