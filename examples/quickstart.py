"""Quickstart: the count-sketch optimizer as a drop-in (paper §4).

    PYTHONPATH=src python examples/quickstart.py

Shows: (1) the Count-Sketch Tensor's UPDATE/QUERY on a power-law vector,
(2) the composable store/transform API — ``chain(clip, scale_by_adam(
m_store=CountSketchStore(...), v_store=CountMinStore(...)),
scale_by_lr(...))`` — next to the legacy ``countsketch_adam`` wrapper,
which is the same rule chain minus the clip link (bit-identity of that
pairing is pinned in tests/test_transforms.py), and (3) the memory each
store choice frees.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as cs
from repro.core.optimizers import (adam, apply_updates, countsketch_adam,
                                   state_bytes)
from repro.core.partition import SketchPolicy
from repro.core.stores import CountMinStore, CountSketchStore, Rank1Store
from repro.core.transforms import (chain, clip_by_global_norm, scale_by_adam,
                                   scale_by_lr)


def demo_sketch_tensor():
    print("=== 1. Count-Sketch Tensor (paper Alg. 1) ===")
    n, d = 100_000, 64
    spec = cs.for_param((n, d), compression=20.0, depth=3)
    S = cs.init(spec)
    print(f"table {n}x{d} ({n * d * 4 / 2**20:.1f} MiB) -> sketch "
          f"{spec.shape} ({spec.nbytes() / 2**20:.1f} MiB)")

    # power-law vector: a few heavy rows, long tail
    rng = np.random.RandomState(0)
    ids = jnp.asarray(rng.randint(0, n, size=4096), jnp.int32)
    mags = (rng.zipf(1.5, size=4096).clip(max=1000) / 10.0)
    rows = jnp.asarray(mags[:, None] * rng.randn(4096, d), jnp.float32)
    S = cs.update(spec, S, ids, rows)

    hot = np.argsort(-mags)[:5]
    est = cs.query(spec, S, ids[jnp.asarray(hot)])
    for i, h in enumerate(hot):
        err = float(jnp.linalg.norm(est[i] - rows[h]) /
                    jnp.linalg.norm(rows[h]))
        print(f"  heavy row |x|={mags[h]:7.1f}: rel err {err:.3f}")


def demo_composable_optimizer():
    print("\n=== 2. Composable store/transform API (paper Alg. 4) ===")
    key = jax.random.PRNGKey(0)
    params = {
        "tok_embed": {"table": jax.random.normal(key, (50_000, 64)) * 0.02},
        "lm_head": {"table": jax.random.normal(key, (50_000, 64)) * 0.02},
        "body": jax.random.normal(key, (64, 64)),
    }
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(key, p.shape) * 0.01, params)
    policy = SketchPolicy(min_rows=1024)        # embedding+softmax only

    # the update rule (Adam) composed with its moment STORES: 1st moment
    # in a signed Count-Sketch, 2nd in a Count-Min — the paper's CS-MV —
    # at 5x compression, clipped and lr-scheduled, all one chain.
    composed = chain(
        clip_by_global_norm(1.0),
        scale_by_adam(m_store=CountSketchStore(compression=5.0),
                      v_store=CountMinStore(compression=5.0),
                      where=policy),
        scale_by_lr(1e-3))

    # swapping a store swaps the memory/accuracy trade-off — the rule is
    # untouched.  Rank1Store is the Adafactor-style LR-NMF-V baseline.
    rank1 = chain(
        clip_by_global_norm(1.0),
        scale_by_adam(v_store=Rank1Store(), where=policy),
        scale_by_lr(1e-3))

    # the legacy wrapper: the same adam+lr chain behind a policy bridge
    # (no clip link, so its trajectory differs from `composed` exactly by
    # the clipping; state memory is identical)
    legacy = countsketch_adam(1e-3, policy=policy)

    for name, opt in [("dense Adam      ", adam(1e-3)),
                      ("CS-Adam (chain) ", composed),
                      ("rank-1 V (chain)", rank1),
                      ("CS-Adam (legacy)", legacy)]:
        st = opt.init(params)
        p = params
        for _ in range(3):
            updates, st = opt.update(grads, st, p)
            p = apply_updates(p, updates)
        mb = state_bytes(st) / 2**20
        print(f"  {name}: optimizer state {mb:7.2f} MiB")
    print("  (the paper's LM1B run saves 25% of total training memory"
          " this way)")


if __name__ == "__main__":
    demo_sketch_tensor()
    demo_composable_optimizer()
