"""Quickstart: the count-sketch optimizer as a drop-in (paper §4).

    PYTHONPATH=src python examples/quickstart.py

Shows: (1) the Count-Sketch Tensor's UPDATE/QUERY on a power-law vector,
(2) swapping dense Adam for CS-Adam on a model with a big embedding
table, and (3) the memory the sketch frees.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as cs
from repro.core.optimizers import (SketchHParams, adam, apply_updates,
                                   countsketch_adam, state_bytes)
from repro.core.partition import SketchPolicy


def demo_sketch_tensor():
    print("=== 1. Count-Sketch Tensor (paper Alg. 1) ===")
    n, d = 100_000, 64
    spec = cs.for_param((n, d), compression=20.0, depth=3)
    S = cs.init(spec)
    print(f"table {n}x{d} ({n * d * 4 / 2**20:.1f} MiB) -> sketch "
          f"{spec.shape} ({spec.nbytes() / 2**20:.1f} MiB)")

    # power-law vector: a few heavy rows, long tail
    rng = np.random.RandomState(0)
    ids = jnp.asarray(rng.randint(0, n, size=4096), jnp.int32)
    mags = (rng.zipf(1.5, size=4096).clip(max=1000) / 10.0)
    rows = jnp.asarray(mags[:, None] * rng.randn(4096, d), jnp.float32)
    S = cs.update(spec, S, ids, rows)

    hot = np.argsort(-mags)[:5]
    est = cs.query(spec, S, ids[jnp.asarray(hot)])
    for i, h in enumerate(hot):
        err = float(jnp.linalg.norm(est[i] - rows[h]) /
                    jnp.linalg.norm(rows[h]))
        print(f"  heavy row |x|={mags[h]:7.1f}: rel err {err:.3f}")


def demo_optimizer():
    print("\n=== 2. CS-Adam as a drop-in (paper Alg. 4) ===")
    key = jax.random.PRNGKey(0)
    params = {
        "tok_embed": {"table": jax.random.normal(key, (50_000, 64)) * 0.02},
        "lm_head": {"table": jax.random.normal(key, (50_000, 64)) * 0.02},
        "body": jax.random.normal(key, (64, 64)),
    }

    dense = adam(1e-3)
    sketched = countsketch_adam(
        1e-3,
        policy=SketchPolicy(min_rows=1024),          # embedding+softmax only
        hparams=SketchHParams(compression=5.0))      # the paper's LM setting

    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(key, p.shape) * 0.01, params)
    for name, opt in [("dense Adam", dense), ("CS-Adam  ", sketched)]:
        st = opt.init(params)
        for _ in range(3):
            updates, st = opt.update(grads, st, params)
            params2 = apply_updates(params, updates)
        mb = state_bytes(st) / 2**20
        print(f"  {name}: optimizer state {mb:7.2f} MiB")
    print("  (the paper's LM1B run saves 25% of total training memory"
          " this way)")


if __name__ == "__main__":
    demo_sketch_tensor()
    demo_optimizer()
