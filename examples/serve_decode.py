"""Serving example: prefill a batch of prompts, then batched greedy
decode against the KV cache — the ``serve_step`` exercised by the
decode_32k / long_500k dry-run cells, at CPU scale.

    PYTHONPATH=src python examples/serve_decode.py --arch yi_9b
    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6_7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.serve.steps import make_serve_step
from repro.train.steps import family_module


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    mod = family_module(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    max_seq = args.prompt_len + args.new_tokens
    ss = make_serve_step(cfg, batch=args.batch, max_seq=max_seq)

    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(1, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((args.batch, cfg.n_patches, cfg.d_model))

    prefill = jax.jit(ss.prefill_fn)
    decode = jax.jit(ss.decode_fn)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    generated = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    toks_s = args.batch * (args.new_tokens - 1) / t_decode
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} toks: {t_prefill * 1e3:.1f} ms "
          f"(includes compile)")
    print(f"decode  {args.new_tokens - 1} steps: {t_decode * 1e3:.1f} ms "
          f"-> {toks_s:.1f} tok/s")
    print(f"sample continuation (seq 0): "
          f"{[int(g[0]) for g in generated[:10]]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
