"""Fault-tolerance walkthrough: crash, restore, and ELASTIC restore with
the Hokusai sketch fold (paper §5).

    PYTHONPATH=src python examples/elastic_recovery.py

1. trains with CS-Adam, checkpointing every 20 steps;
2. simulates a crash at step 50, restores at step 40, resumes — losses
   match the uninterrupted run exactly (deterministic zipf stream);
3. simulates losing a quarter of the fleet: ``plan_resize`` shrinks the
   data axis and requests a sketch FOLD — optimizer state halves while
   preserving accumulated moments, and training continues.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core import optimizers as O
from repro.core.partition import SketchPolicy
from repro.data import ZipfLM, ZipfLMConfig
from repro.distributed.elastic import plan_resize
from repro.models import transformer as tf
from repro.models.config import ArchConfig
from repro.train.trainer import Trainer, TrainerConfig, TrainState

CFG = ArchConfig(name="demo", family="gqa", n_layers=2, d_model=128,
                 n_heads=4, n_kv=2, head_dim=32, d_ff=512, vocab_size=4096,
                 vocab_multiple=64, attn_chunk=64, loss_chunk=64,
                 compute_dtype="float32")
HP = O.SketchHParams(compression=4.0, width_multiple=16)
POL = SketchPolicy(min_rows=512)


def make_pieces():
    opt = O.countsketch_adam(1e-3, policy=POL, hparams=HP)
    params = tf.init(jax.random.PRNGKey(0), CFG)

    @jax.jit
    def step_fn(params, st, batch):
        def loss_fn(p):
            return tf.train_loss(CFG, p, batch, remat=False)
        l, g = jax.value_and_grad(loss_fn)(params)
        u, st = opt.update(g, st, params)
        return O.apply_updates(params, u), st, {"loss": l}

    data = ZipfLM(ZipfLMConfig(vocab_size=CFG.vocab, seq_len=64,
                               global_batch=4))
    return opt, params, step_fn, data


def main() -> int:
    opt, params, step_fn, data = make_pieces()

    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(total_steps=60, ckpt_dir=d, ckpt_every=20,
                             ckpt_async=False)
        # --- crash + recovery --------------------------------------------
        tr = Trainer(step_fn, data, tcfg, fail_at=50)
        st0 = TrainState(0, params, opt.init(params))
        try:
            tr.fit(st0)
        except RuntimeError as e:
            print(f"[1] simulated failure: {e}")
        resumed = tr.restore_or_init(st0)
        print(f"[1] restored at step {resumed.step}; resuming...")
        out = tr.fit(resumed)
        print(f"[1] finished at step {out.step}, "
              f"loss {tr.history[-1]['loss']:.3f}")

        # --- elastic resize + sketch fold ---------------------------------
        plan = plan_resize(available_chips=192, model_axis=16,
                           old_data_axis=16)
        print(f"[2] lost 64/256 chips -> new mesh data={plan.data_axis} "
              f"model={plan.model_axis}, fold_sketch={plan.fold_sketch}")
        before = O.state_bytes(out.opt_state)
        folded = store.fold_sketches(
            {"opt_state": out.opt_state}, store.default_is_sketch)["opt_state"]
        after = O.state_bytes(folded)
        print(f"[2] sketch fold: optimizer state {before / 2**20:.2f} MiB "
              f"-> {after / 2**20:.2f} MiB")

        # continue training with the folded state (width halved => new
        # hparams view); estimates are preserved by fold exactness.
        hp2 = O.SketchHParams(compression=HP.compression * 2,
                              width_multiple=HP.width_multiple // 2 or 8)
        opt2 = O.countsketch_adam(1e-3, policy=POL, hparams=hp2)
        st2 = {"step": out.opt_state["step"], "m": folded["m"],
               "v": folded["v"]}

        @jax.jit
        def step2(params, st, batch):
            def loss_fn(p):
                return tf.train_loss(CFG, p, batch, remat=False)
            l, g = jax.value_and_grad(loss_fn)(params)
            u, st = opt2.update(g, st, params)
            return O.apply_updates(params, u), st, {"loss": l}

        p2 = out.params
        for i in range(60, 70):
            b = data.batch(i)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            p2, st2, m = step2(p2, st2, b)
        print(f"[2] trained 10 more steps on the folded state, "
              f"loss {float(m['loss']):.3f} — no reset, no divergence")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
