"""Shared benchmark plumbing: a small-LM training harness driven by the
deterministic zipf stream, timing helpers, and result persistence.

Every benchmark mirrors one paper table/figure's *protocol* at CPU scale
(DESIGN.md §9); results land in experiments/bench/<name>.json and are
summarized by ``python -m benchmarks.run``.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import optimizers as O
from repro.data import ZipfLM, ZipfLMConfig
from repro.models import transformer as tf
from repro.models.config import ArchConfig

OUT_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"


def save_result(name: str, payload: Dict[str, Any]) -> pathlib.Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=float))
    return path


def small_lm_cfg(vocab: int = 2048, d_model: int = 128, n_layers: int = 2,
                 **kw) -> ArchConfig:
    base = dict(name="bench-lm", family="gqa", n_layers=n_layers,
                d_model=d_model, n_heads=4, n_kv=2, head_dim=d_model // 4,
                d_ff=4 * d_model, vocab_size=vocab, vocab_multiple=64,
                attn_chunk=64, loss_chunk=64, compute_dtype="float32",
                sketch_compression=5.0)
    base.update(kw)
    return ArchConfig(**base)


def train_small_lm(opt: O.Transform, *, cfg: Optional[ArchConfig] = None,
                   steps: int = 300, batch: int = 8, seq: int = 64,
                   seed: int = 0, eval_every: int = 0,
                   collect_aux: Optional[Callable] = None) -> Dict[str, Any]:
    """Train a small LM on the zipf stream; returns losses / eval ppl /
    state bytes / wall time (one jit'd step, timed after warmup)."""
    if steps < 2:
        # step 0 is compile warmup; the timer starts at step 1.  With
        # fewer than 2 steps there are ZERO measured iterations and the
        # old code silently reported wall≈0 / steps_per_s=0 — a benchmark
        # that "ran" but measured nothing.  Fail loudly instead.
        raise ValueError(f"train_small_lm needs steps >= 2 (got {steps}): "
                         f"step 0 is warmup, timing starts at step 1")
    cfg = cfg or small_lm_cfg()
    params = tf.init(jax.random.PRNGKey(seed), cfg)
    data = ZipfLM(ZipfLMConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                               global_batch=batch, seed=seed))
    eval_data = ZipfLM(ZipfLMConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                    global_batch=batch, seed=seed + 999))
    st = opt.init(params)

    @jax.jit
    def step(params, st, tokens, labels):
        def loss_fn(p):
            return tf.train_loss(cfg, p, {"tokens": tokens, "labels": labels},
                                 remat=False)
        l, g = jax.value_and_grad(loss_fn)(params)
        g = O.clip_by_global_norm(1.0)(g)
        u, st = opt.update(g, st, params)
        return O.apply_updates(params, u), st, l, g

    @jax.jit
    def eval_loss(params, tokens, labels):
        return tf.train_loss(cfg, params, {"tokens": tokens,
                                           "labels": labels}, remat=False)

    losses: List[float] = []
    evals: List[Dict[str, float]] = []
    aux_log: List[Any] = []
    t0 = None
    for i in range(steps):
        b = data.batch(i)
        params, st, l, g = step(params, st, jnp.asarray(b["tokens"]),
                                jnp.asarray(b["labels"]))
        if i == 1:
            jax.block_until_ready(l)
            t0 = time.perf_counter()
        losses.append(float(l))
        if collect_aux is not None and i % 25 == 0:
            aux_log.append(collect_aux(i, g, st))
        if eval_every and (i + 1) % eval_every == 0:
            ls = []
            for j in range(4):
                eb = eval_data.batch(j)
                ls.append(float(eval_loss(params, jnp.asarray(eb["tokens"]),
                                          jnp.asarray(eb["labels"]))))
            evals.append({"step": i + 1, "loss": float(np.mean(ls)),
                          "ppl": float(np.exp(np.mean(ls)))})
    # `l` is always bound and t0 always set (steps >= 2 enforced above);
    # the old `losses and l` guard skipped the device sync entirely when
    # the loop hadn't run, and `t0 or ...` turned that into wall ≈ 0
    jax.block_until_ready(l)
    wall = time.perf_counter() - t0
    return {
        "final_loss": float(np.mean(losses[-20:])),
        "final_ppl": float(np.exp(np.mean(losses[-20:]))),
        "losses": losses[:: max(1, len(losses) // 50)],
        "evals": evals,
        "opt_state_bytes": O.state_bytes(st),
        "steps_per_s": (steps - 1) / wall if wall > 0 else 0.0,
        "aux": aux_log,
        "params": params, "opt_state": st,
    }


def strip_arrays(result: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v for k, v in result.items()
            if k not in ("params", "opt_state", "aux")}
