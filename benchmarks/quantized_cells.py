"""(ours) Quantized sketch cells — bytes/step vs steps/s ladder
(DESIGN.md §18).

Protocol: one sketched (n, d) table under ``scale_by_adam`` (both
moments sketched, fused 'xla' backend, state donated), dense full-table
gradients — the same optimizer-update-only timing as
``benchmarks/fused_store.py``, swept over the cell dtype axis:

  equal width   f32 / bf16 / int8 at compression 5× — same buckets and
                seeds, so the quantized arms differ from f32 ONLY by
                cell precision; bytes shrink 2× / ~4×.
  equal bytes   bf16 at 2× width, int8 at ~4× width — the planner's
                water-fill answer (``--sketch-dtype int8`` doubles twice
                the width at a fixed byte budget), trading rounding
                noise for fewer collisions.

Per arm: steps/s (interleaved A/B windows, min-over-windows — see
§FusedStore calibration), process-CPU ms/step, measured sketch state
bytes (the dense fused path reads AND rewrites every cell each step, so
state bytes are the per-step sketch traffic), and a quality pass — the
recovered 2nd moment's rel-L1 vs the f32 arm after a shared gradient
stream, checked against the probe's quantization-noise envelope
(dim·scale/4 per read, ``obs.probes`` gauge units).

The LLC-inversion shape 65536×64 is where the f32 fused one-shot's
working set outgrows the cache: int8 cells pull it back in and win on
wall clock, not just on bytes.  Results:
experiments/bench/quantized_cells.json.

    PYTHONPATH=src python benchmarks/quantized_cells.py --quick
    PYTHONPATH=src python -m benchmarks.quantized_cells --pin  # committed
"""
from __future__ import annotations

import os
import sys

if "--pin" in sys.argv:                      # before jax initializes
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false"
                               ).strip()
    try:
        os.sched_setaffinity(0, {0})
    except (AttributeError, OSError):        # non-Linux hosts
        pass

import argparse
import time

import jax
import jax.numpy as jnp

try:
    from benchmarks.common import save_result
except ImportError:  # run as a script: python benchmarks/quantized_cells.py
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import save_result
from repro.core import optimizers as O
from repro.core import quantize as qz
from repro.core import sketch as cs
from repro.core.stores import CountMinStore, CountSketchStore, StoreTree

SHAPES = ((16384, 64), (65536, 64))
BASE_COMPRESSION = 5.0
# (arm name, cell dtype, width multiplier): equal-width arms at 1x; the
# equal-bytes arms grow width by the byte ratio (bf16 2x, int8 ~4x —
# the int8 arm's per-block scales make it "equal" only to ~1%)
ARMS = (("f32", "float32", 1),
        ("bf16_eqwidth", "bfloat16", 1),
        ("int8_eqwidth", "int8", 1),
        ("bf16_eqbytes", "bfloat16", 2),
        ("int8_eqbytes", "int8", 4))


def _tree(dtype: str, wmul: int):
    c = BASE_COMPRESSION / wmul
    return StoreTree.select(
        m=CountSketchStore(compression=c, backend="xla", dtype=dtype),
        v=CountMinStore(compression=c, backend="xla", dtype=dtype),
        where=lambda p, s: True)


def _state_bytes(state) -> int:
    """Measured sketch state bytes: every cell + scale buffer the dense
    fused path touches per step (QuantState flattens to cells+scales)."""
    return sum(leaf.nbytes for part in ("m", "v")
               for leaf in jax.tree_util.tree_leaves(state[part]))


def _prepare(dtype: str, wmul: int, n: int, d: int):
    opt = O.adam_from_stores(1e-3, _tree(dtype, wmul))
    params = {"table": jax.random.normal(jax.random.PRNGKey(0), (n, d))}
    g = {"table": jax.random.normal(jax.random.PRNGKey(1), (n, d)) * 0.1}
    state = opt.init(params)
    nbytes = _state_bytes(state)
    step = jax.jit(lambda g, s: opt.update(g, s), donate_argnums=(1,))
    u, state = step(g, state)
    jax.block_until_ready(u)                     # compile + warm
    return [step, g, state, nbytes]


def bench_shape(n: int, d: int, arms, steps: int, windows: int = 5):
    """{arm: (steps/s, cpu ms/step, state bytes)} — interleaved A/B
    windows, min-over-windows (co-tenant noise only ever ADDS time)."""
    runs = {a: _prepare(dt, wm, n, d) for a, dt, wm in arms}
    wall = {a: float("inf") for a, _, _ in arms}
    cpu = {a: float("inf") for a, _, _ in arms}
    for _ in range(windows):
        for a, _, _ in arms:
            step, g, state, _ = runs[a]
            c0, t0 = time.process_time(), time.perf_counter()
            for _ in range(steps):
                u, state = step(g, state)
            jax.block_until_ready(u)
            wall[a] = min(wall[a], (time.perf_counter() - t0) / steps)
            cpu[a] = min(cpu[a], (time.process_time() - c0) / steps)
            runs[a][2] = state
    return {a: (1.0 / wall[a], cpu[a] * 1000.0, runs[a][3])
            for a, _, _ in arms}


def quality_pass(n: int, d: int, steps: int = 24, sample: int = 2048):
    """rel-L1 of the recovered 2nd moment vs the f32 arm, against the
    probe's quantization-noise envelope.  Equal-width arms share the f32
    arm's seed and width, so buckets coincide and the difference is
    PURELY cell precision + stochastic rounding."""
    shape = (n, d)

    def spec_for(dtype):
        return cs.for_param(shape, compression=BASE_COMPRESSION,
                            signed=False, seed=17,
                            dtype=jnp.dtype(dtype))

    key = jax.random.PRNGKey(2)
    rows = jax.random.permutation(key, n)[:sample].astype(jnp.int32)
    streams = [jax.random.normal(jax.random.PRNGKey(100 + t),
                                 (256, d)) * 0.1 for t in range(steps)]
    ids = [jax.random.randint(jax.random.PRNGKey(200 + t), (256,), 0, n)
           for t in range(steps)]
    states = {}
    for dtype in ("float32", "bfloat16", "int8"):
        spec = spec_for(dtype)
        S = cs.init(spec)
        for t in range(steps):
            sr = qz.step_seed(spec.seed, jnp.uint32(t + 1))
            S = cs.update(spec, S, ids[t],
                          (1.0 - 0.999) * streams[t] ** 2, sr_seed=sr)
        states[dtype] = (spec, S)
    fspec, fS = states["float32"]
    ref = cs.query(fspec, fS, rows)
    out = {}
    for dtype in ("bfloat16", "int8"):
        spec, S = states[dtype]
        est = cs.query(spec, S, rows)
        rel = float(jnp.sum(jnp.abs(est - ref))
                    / (jnp.sum(jnp.abs(ref)) + 1e-12))
        row = {"v_rel_l1_vs_f32": rel}
        if spec.quantized:
            # an unsigned int8 read resolves a cell to within HALF its
            # block scale: SR noise (E| |=s/4) plus the half-ulp read
            # floor that protects adaptive denominators.  Each touch
            # re-rounds the cell, so deviations random-walk with the
            # touch count — the calibrated bound is 2x the per-read
            # resolution (two ulps) at these touch rates; the realized
            # ratio is emitted so drift is visible in the artifact
            b = spec.family.bucket(rows)
            sc = qz.bucket_scales(S.scales, b, spec.scale_block)
            env = float(jnp.sum(d * jnp.min(sc, axis=0) / 2.0)
                        / (jnp.sum(jnp.abs(ref)) + 1e-12))
            row["quant_noise_envelope"] = env
            row["envelope_ratio"] = round(rel / max(env, 1e-12), 4)
            row["within_envelope"] = rel <= 2.0 * env
        out[dtype] = row
    return out


def run(quick: bool = False, shapes=SHAPES):
    steps = 5 if quick else 10
    out = {}
    for n, d in shapes:
        res = bench_shape(n, d, ARMS, steps, windows=3 if quick else 5)
        row = {}
        f32_sps, _, f32_bytes = res["f32"]
        for a, dt, wm in ARMS:
            sps, cpu_ms, nbytes = res[a]
            row[a] = {
                "cell_dtype": dt, "width_multiplier": wm,
                "steps_per_s": round(sps, 3),
                "cpu_ms_per_step": round(cpu_ms, 2),
                "sketch_bytes_per_step": nbytes,
                "bytes_reduction_vs_f32": round(f32_bytes / nbytes, 3),
                "speedup_vs_f32": round(sps / f32_sps, 3),
            }
        out[f"{n}x{d}"] = {"n": n, "dim": d, "arms": row,
                           "quality": quality_pass(
                               n, d, steps=8 if quick else 24,
                               sample=512 if quick else 2048)}
    flag = out.get("65536x64", next(iter(out.values())))
    i8 = flag["arms"].get("int8_eqwidth", {})
    summary = {
        "protocol": "scale_by_adam on one sketched table, optimizer "
                    "update only, state donated, fused 'xla' backend; "
                    "interleaved A/B windows, min-over-windows; equal-"
                    "width arms share buckets with f32 (seeded), so "
                    "quality deltas are pure cell precision",
        "pinned": "--pin" in sys.argv,
        "device": jax.default_backend(),
        "steps_timed": steps,
        "rows": out,
        "int8_bytes_reduction_at_flagship":
            i8.get("bytes_reduction_vs_f32"),
        "int8_speedup_at_flagship": i8.get("speedup_vs_f32"),
        "flagship_shape": "65536x64",
    }
    save_result("quantized_cells", summary)
    return {k: {a: (r["steps_per_s"],
                    f"{r['bytes_reduction_vs_f32']}x bytes")
                for a, r in v["arms"].items()}
            for k, v in out.items()}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--pin", action="store_true",
                    help="pin to one core + single-threaded XLA (stable "
                         "work-ratio protocol; handled before jax init)")
    ap.add_argument("--shapes", default="",
                    help="comma-separated NxD overrides, e.g. 65536x64")
    a = ap.parse_args()
    shapes = SHAPES
    if a.shapes:
        shapes = tuple(tuple(int(x) for x in s.split("x"))
                       for s in a.shapes.split(","))
    print(run(quick=a.quick, shapes=shapes))
