"""Paper §7.3 / Table 8: extreme classification with MACH + CS-RMSProp.

Protocol at CPU scale: 200k classes hashed into R=2 meta-classifiers of
2k meta-classes (MACH; ``repro.core.hashing.mach_class_hash``).  Each
meta-classifier: sparse zipf features → embedding-sum → meta logits.
Compare:

  adam_small_batch   — dense Adam, batch B (the memory-limited baseline)
  cs_big_batch       — β₁=0 CS-RMSProp (Theorem 5.1 optimizer, 2nd moment
                       CMS at 1% size) with batch 3.5·B — the memory the
                       sketch frees goes to batch size, as in the paper.

Both arms run the PR-3 ``chain``/``AuxStore`` transforms — the exact
code path training executes (``--store-backend`` routes the sketched
arm's fused ``update_read`` through the kernel registry).  Inference
aggregates per-replica meta-class LOG-SOFTMAX (``mach_log_scores``), not
raw logits: replicas with different logit scales would be miscalibrated
under raw summation.  Reports recall@10 over a down-sampled candidate
set, per-replica losses, and aux-state bytes.

The production-scale version of this protocol (multi-million-row meta
table, sampled softmax, batch-size sweep to the memory wall) lives in
``benchmarks/extreme_scale.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core import optimizers as O
from repro.core import transforms as T
from repro.core.hashing import mach_class_hash
from repro.core.partition import SketchPolicy
from repro.core.stores import CountMinStore, StoreTree
from repro.data import classification_batch
from repro.train.extreme import mach_log_scores

N_CLASSES = 200_000
N_FEATURES = 20_000
N_META = 2_048
R = 2
D_EMB = 64
POL = SketchPolicy(min_rows=1024)


def _cs_rmsprop(lr, backend=None):
    """The β₁=0 Theorem 5.1 optimizer on the composable API: CMS 2nd
    moment at 1% size on every policy-matched table, m dropped —
    ``chain(scale_by_rmsprop(stores=...), scale_by_lr(lr))``."""
    stores = StoreTree.select(
        m=None,
        v=CountMinStore(compression=100.0, width_multiple=16,
                        backend=backend),
        where=POL, default_m=None)
    return T.chain(T.scale_by_rmsprop(stores=stores), T.scale_by_lr(lr))


def _dense_adam(lr):
    return T.chain(T.scale_by_adam(), T.scale_by_lr(lr))


def _init(seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "tok_embed": {"table": jax.random.normal(k1, (N_FEATURES, D_EMB))
                      * 0.05},
        "class_head": {"table": jax.random.normal(k2, (N_META, D_EMB))
                       * 0.05},
    }


def _forward(params, feats):
    emb = params["tok_embed"]["table"][feats].sum(axis=1)     # (B, D)
    return emb @ params["class_head"]["table"].T               # (B, N_META)


def _train_one(opt, class_map, steps, batch):
    params = _init(0)
    st = opt.init(params)

    @jax.jit
    def step(params, st, feats, meta_y):
        def loss(p):
            logits = _forward(p, feats)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, meta_y[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)
        l, g = jax.value_and_grad(loss)(params)
        u, st = opt.update(g, st, params)
        return O.apply_updates(params, u), st, l

    t0 = time.perf_counter()
    for i in range(steps):
        b = classification_batch(i, n_features=N_FEATURES,
                                 n_classes=N_CLASSES, batch=batch)
        meta_y = jnp.asarray(class_map[b["labels"]], jnp.int32)
        params, st, l = step(params, st, jnp.asarray(b["features"]), meta_y)
    jax.block_until_ready(l)
    return params, st, time.perf_counter() - t0, float(l)


def _recall_at(params_list, class_maps, k=10, n_eval=200, candidates=2000):
    """MACH inference: aggregate per-replica meta-class log-probabilities
    over a down-sampled candidate set containing the true classes (the
    paper's evaluation shortcut; calibration via ``mach_log_scores``)."""
    rng = np.random.RandomState(123)
    hits = 0
    for j in range(4):
        b = classification_batch(50_000 + j, n_features=N_FEATURES,
                                 n_classes=N_CLASSES, batch=n_eval // 4)
        cand = np.unique(np.concatenate(
            [b["labels"], rng.randint(0, N_CLASSES, size=candidates)]))
        logits_list = [
            np.asarray(_forward(params, jnp.asarray(b["features"])))
            for params in params_list]
        agg = mach_log_scores(logits_list, class_maps, cand)
        topk = np.argsort(-agg, axis=1)[:, :k]
        for i, y in enumerate(b["labels"]):
            pos = np.where(cand == y)[0][0]
            hits += int(pos in topk[i])
    return hits / n_eval


def run(quick: bool = False, backend: str = None):
    steps = 60 if quick else 450
    base_batch = 128
    out = {}
    for name, make_opt, batch, step_scale in [
        ("adam_small_batch", lambda: _dense_adam(2e-2), base_batch, 1.0),
        ("cs_big_batch", lambda: _cs_rmsprop(2e-2, backend=backend),
         int(base_batch * 3.5), 3.5),
    ]:
        params_list, maps, bytes_, t = [], [], 0, 0.0
        replica_losses = []
        n_steps = max(10, int(steps / step_scale))  # same #examples seen
        for r in range(R):
            cmap = mach_class_hash(seed=r, num_classes=N_CLASSES,
                                   num_buckets=N_META, num_hashes=1)[0]
            params, st, dt, loss = _train_one(make_opt(), cmap, n_steps,
                                              batch)
            params_list.append(params)
            maps.append(cmap)
            bytes_ += O.state_bytes(st)
            t += dt
            replica_losses.append(loss)
        out[name] = {
            "recall_at_10": _recall_at(params_list, maps),
            "aux_bytes": bytes_,
            "train_time_s": round(t, 2),
            "batch": batch,
            "steps": n_steps,
            "replica_losses": replica_losses,
        }
    out["batch_ratio"] = out["cs_big_batch"]["batch"] / base_batch
    out["bytes_ratio"] = (out["cs_big_batch"]["aux_bytes"]
                          / out["adam_small_batch"]["aux_bytes"])
    save_result("extreme", out)
    return {k: v for k, v in out.items() if not isinstance(v, dict)} | {
        k: {"recall@10": v["recall_at_10"], "aux_MB": v["aux_bytes"] / 2**20}
        for k, v in out.items() if isinstance(v, dict)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--store-backend", default=None,
                    help="kernel backend for the sketched arm's fused "
                         "update_read ('ref' | 'xla' | 'tiled' | "
                         "'interpret' | 'auto'); None = composed fallback")
    a = ap.parse_args()
    print(run(quick=a.quick, backend=a.store_backend))
