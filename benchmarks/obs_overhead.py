"""(ours) Observability overhead — metrics-on vs metrics-off steps/s A/B
(DESIGN.md §15).

Protocol: the sparse_embedding launcher workload (zipf ids over a
sketched (n, d) table, CS-Adam sparse-rows step) at a production-
representative shape — a 64k-row table, d=64, 2048 ids/step — run twice
with the SAME jit'd step shape:

  off   bare loop — no writer, no probe, no phase spans
  on    full telemetry at the default ``log_every=10``: shadow probe
        state (K=16 rows) inside the jit'd step, RunObserver windowing
        every step's host record, table-stats + probe-error host fetch
        and a JSONL write at every log boundary, phase spans around the
        loop

The telemetry contract is that everything between log boundaries stays
on device, so the A/B should be within noise; the acceptance target for
the committed run is < 2% median overhead.  Wall-clock on this shared
CPU container drifts by >10% over seconds, so arm-level A/B (run all of
off, then all of on) measures the container, not the telemetry.  The
protocol instead interleaves at segment granularity: both arms' jitted
steps stay live, and the loop alternates one 2·log_every-step segment
of each (every ON segment contains exactly two log boundaries, so the
boundary cost is fully represented).  Adjacent segments see the same
machine state; the committed JSON reports the median over all segment
pairs.  Results: experiments/bench/obs_overhead.json.

    PYTHONPATH=src python -m benchmarks.obs_overhead --quick
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import save_result
except ImportError:  # run as a script: python benchmarks/obs_overhead.py
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import save_result

from repro.data import ZipfLM, ZipfLMConfig
from repro.obs import (MetricsWriter, PhaseTimer, RunObserver, TableMonitor,
                       TableProbe, predicted_table_errors)
from repro.train.steps import (make_sparse_embedding_step,
                               sparse_embedding_stores)

N_ROWS, DIM, BATCH, SEQ = 65536, 64, 32, 64
LOG_EVERY, PROBE_ROWS = 10, 16


def _build(with_probe: bool):
    init_fn, step_fn, opt = make_sparse_embedding_step(N_ROWS, DIM, lr=1e-3)
    table = init_fn(jax.random.PRNGKey(0))
    target = init_fn(jax.random.PRNGKey(1))
    probe = (TableProbe.for_table("sparse_embedding", N_ROWS, k=PROBE_ROWS)
             if with_probe else None)
    opt_state = opt.init()
    if probe is not None:
        opt_state = dict(opt_state, probe=probe.init(DIM))

    def train_step(table, opt_state, ids):
        rows = table[ids] - target[ids]
        loss = jnp.mean(jnp.square(rows))
        inner = {k: v for k, v in opt_state.items() if k != "probe"}
        table, inner = step_fn(table, inner, ids, rows)
        if probe is not None:
            inner = dict(inner, probe=probe.update(opt_state["probe"],
                                                   ids, rows))
        return table, inner, {"loss": loss}

    return jax.jit(train_step, donate_argnums=(0, 1)), table, opt_state, probe


SEG = 2 * LOG_EVERY  # segment = exactly two log boundaries


def _segment_pairs(n_pairs: int, *, seed: int = 0) -> list:
    """Run both arms segment-interleaved; returns per-pair overheads.

    Iteration timing for the ON arm includes the host-side observer work
    (windowing + boundary fetch + JSONL write), so the full telemetry
    cost lands in every ON segment.  Both arms consume the SAME ids per
    in-segment position, so the compared work is identical."""
    off_step, off_table, off_state, _ = _build(with_probe=False)
    on_step, on_table, on_state, probe = _build(with_probe=True)
    data = ZipfLM(ZipfLMConfig(vocab_size=N_ROWS, seq_len=SEQ,
                               global_batch=BATCH, seed=seed))
    tmp = tempfile.TemporaryDirectory()
    m_store, v_store = sparse_embedding_stores(N_ROWS, DIM)
    mon = TableMonitor(
        path="sparse_embedding", m_store=m_store, v_store=v_store,
        probe=probe,
        predicted=predicted_table_errors(m_store, v_store, N_ROWS))
    observer = RunObserver(MetricsWriter(tmp.name, run_meta={"bench": 1}),
                           monitors=[mon], log_every=LOG_EVERY,
                           phase_timer=PhaseTimer())

    def one_ids(i):
        b = data.batch(i)
        return jnp.asarray(b["tokens"]).reshape(-1).astype(jnp.int32)

    # warmup covers both train-step compiles AND the monitor's one-time
    # collect-fn compile at the first log boundary — steady-state
    # telemetry cost is the claim, not jit compilation
    on_i = 0
    for w in range(LOG_EVERY + 1):
        ids = one_ids(w)
        off_table, off_state, m = off_step(off_table, off_state, ids)
        float(m["loss"])  # both arms record loss history — every real
        on_i += 1         # training loop does; the A/B isolates telemetry
        t = time.perf_counter()
        on_table, on_state, m = on_step(on_table, on_state, ids)
        jax.block_until_ready(m["loss"])
        observer.on_step(on_i, {"step": on_i,
                                "time_s": time.perf_counter() - t,
                                "loss": float(m["loss"])}, on_state)

    pairs = []
    for p in range(n_pairs):
        ids_seg = [one_ids(LOG_EVERY + 1 + p * SEG + j) for j in range(SEG)]
        t0 = time.perf_counter()
        for ids in ids_seg:
            off_table, off_state, m = off_step(off_table, off_state, ids)
            float(m["loss"])  # see warmup note: loss history in both arms
        t_off = time.perf_counter() - t0
        t0 = time.perf_counter()
        for ids in ids_seg:
            on_i += 1
            t = time.perf_counter()
            on_table, on_state, m = on_step(on_table, on_state, ids)
            jax.block_until_ready(m["loss"])
            observer.on_step(on_i, {"step": on_i,
                                    "time_s": time.perf_counter() - t,
                                    "loss": float(m["loss"])}, on_state)
        t_on = time.perf_counter() - t0
        pairs.append({"step_ms_off": t_off / SEG * 1e3,
                      "step_ms_on": t_on / SEG * 1e3,
                      "overhead": (t_on - t_off) / t_off})
    observer.close(on_i, on_state)
    tmp.cleanup()
    return pairs


def run(quick: bool = False, repeats: int = 3) -> str:
    n_pairs = 8 if quick else 16 * max(1, repeats)
    pairs = _segment_pairs(n_pairs)
    med = float(np.median([p["overhead"] for p in pairs]))
    payload = {
        "protocol": {"n_rows": N_ROWS, "dim": DIM, "batch": BATCH,
                     "seq": SEQ, "log_every": LOG_EVERY,
                     "probe_rows": PROBE_ROWS, "segment_steps": SEG,
                     "n_pairs": n_pairs,
                     "scoring": "median over interleaved segment pairs"},
        "pairs": pairs,
        "median_overhead": med,
        "target": "< 0.02 at the default log_every",
    }
    save_result("obs_overhead", payload)
    return (f"median telemetry overhead {med * 100:.2f}% "
            f"({n_pairs} interleaved {SEG}-step segment pairs)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    print(run(quick=args.quick, repeats=args.repeats))
