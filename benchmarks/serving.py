"""Serving replay: SLO curves for online adaptation, dense vs count-min.

The paper's pitch at serve time: a few MB of count-min state instead of a
second (n, d) moment table buys per-user online adaptation at serving
scale.  This benchmark replays the SAME fixed-seed zipf traffic trace
(``repro.serve.traffic``) through the full serving subsystem — bounded
admission, size-or-deadline batching with cross-request dedup,
double-buffered state — against two arms:

  * ``dense``    — full (n, d) 2nd-moment buffer (β₁=0 dense Adam);
  * ``countmin`` — the paper's count-min sketch at ``compression``×.

For each arm × offered load (requests/s on the virtual clock) it records
the real measured adapt-latency histogram (p50/p99), adapt throughput,
virtual request latency (queueing included) and shed rate, then applies
an SLO gate at the NOMINAL (lowest) load: p99 adapt latency under
``slo_p99_ms`` and shed rate under ``shed_slo``.  Higher loads exist to
trace the saturation/shed curve, not to pass.

Results → experiments/bench/serving.json (EXPERIMENTS.md §Serving).
"""
from __future__ import annotations

import argparse
from typing import Any, Dict

try:
    from benchmarks.common import save_result
except ImportError:  # pragma: no cover - script mode
    from common import save_result


def _run_arm(arm: str, trace_cfg, loads, *, compression: float,
             server_kw: Dict[str, Any]) -> Dict[str, Any]:
    import jax

    from repro.core import optimizers as O
    from repro.core.optimizers import SketchHParams
    from repro.serve import (AdaptServer, ServerConfig, make_dense_adapt_step,
                             make_online_adapt_step, make_trace, replay,
                             trace_stats)

    n, d = trace_cfg.n_rows, trace_cfg.dim
    if arm == "dense":
        init_fn, adapt_fn = make_dense_adapt_step(n, d, lr=1e-3)
    else:
        init_fn, adapt_fn = make_online_adapt_step(
            n, d, lr=1e-3, hparams=SketchHParams(compression=compression))

    out: Dict[str, Any] = {"loads": []}
    for load in loads:
        import dataclasses
        tcfg = dataclasses.replace(trace_cfg, offered_load=float(load))
        trace = make_trace(tcfg)
        table = jax.random.normal(jax.random.PRNGKey(trace_cfg.seed),
                                  (n, d)) * 0.1
        opt_state = init_fn()
        if "state_bytes" not in out:
            out["state_bytes"] = int(O.state_bytes(opt_state))
        server = AdaptServer(table, opt_state, adapt_fn,
                             ServerConfig(**server_kw))
        replay(server, trace)
        rec = server.metrics_record(offered_load=float(load))
        rec["trace"] = trace_stats(trace)
        out["loads"].append(rec)
    return out


def run(quick: bool = False) -> str:
    from repro.serve import TraceConfig

    if quick:
        trace_cfg = TraceConfig(n_requests=160, n_users=64, n_rows=2048,
                                dim=16, ids_per_request=8, alpha=1.1, seed=0)
        loads = [100.0, 1000.0]
    else:
        trace_cfg = TraceConfig(n_requests=600, n_users=256, n_rows=16384,
                                dim=32, ids_per_request=8, alpha=1.1, seed=0)
        loads = [100.0, 500.0, 5000.0]
    compression = 5.0
    server_kw = dict(batch_ids=64, max_delay_s=2e-3, queue_cap=32,
                     slo_p99_ms=250.0)
    shed_slo = 0.01

    arms: Dict[str, Any] = {}
    slo: Dict[str, Any] = {}
    for arm in ("dense", "countmin"):
        arms[arm] = _run_arm(arm, trace_cfg, loads, compression=compression,
                             server_kw=server_kw)
        nominal = arms[arm]["loads"][0]      # lowest offered load
        p99 = nominal["adapt_ms"]["p99_ms"]
        shed = nominal["shed_rate"]
        ok = p99 <= server_kw["slo_p99_ms"] and shed <= shed_slo
        slo[arm] = {"offered_load": nominal["offered_load"], "p99_ms": p99,
                    "shed_rate": shed, "pass": bool(ok)}
        print(f"[serving] {arm}: state {arms[arm]['state_bytes']:,} B  "
              f"nominal p99 {p99:.2f} ms  shed {shed:.3f}  "
              f"SLO {'PASS' if ok else 'FAIL'}", flush=True)
        for rec in arms[arm]["loads"][1:]:
            print(f"[serving]   load {rec['offered_load']:.0f}/s: "
                  f"p99 {rec['adapt_ms']['p99_ms']:.2f} ms  "
                  f"adapts/s {rec['reads_per_s']:.1f}  "
                  f"shed {rec['shed_rate']:.3f}", flush=True)

    payload = {
        "config": {"n_rows": trace_cfg.n_rows, "dim": trace_cfg.dim,
                   "n_requests": trace_cfg.n_requests,
                   "ids_per_request": trace_cfg.ids_per_request,
                   "alpha": trace_cfg.alpha, "seed": trace_cfg.seed,
                   "compression": compression, "loads": loads,
                   **server_kw, "shed_slo": shed_slo, "quick": bool(quick)},
        "arms": arms,
        "slo": slo,
    }
    path = save_result("serving", payload)
    ratio = arms["dense"]["state_bytes"] / max(arms["countmin"]["state_bytes"],
                                               1)
    ok_all = all(s["pass"] for s in slo.values())
    return (f"{path} — aux state dense/countmin = {ratio:.1f}x, "
            f"SLO {'PASS' if ok_all else 'FAIL'}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print(run(quick=args.quick))
