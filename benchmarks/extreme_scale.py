"""Tab 8 at production scale: the batch sweep to the memory wall.

The paper's headline systems claim is that sketching the optimizer state
of a 49.5M-class task frees enough memory to grow the mini-batch 3.5×
and finish 38% faster.  This harness reproduces the MECHANISM on a
≥1M-row MACH meta table: two arms run the SAME (ids, rows) train step
(``repro.train.extreme.make_extreme_step``) —

  dense_adam  — full (n, d) Adam m/v buffers (the memory-limited arm)
  cs_rmsprop  — the β₁=0 Theorem 5.1 optimizer, 2nd moment in a
                planner-sized Count-Min sketch

— and the sweep doubles the mini-batch from ``base_batch`` until each
arm hits the memory wall.  "Memory" is the MEASURED requirement of the
compiled step (``jit(...).lower(...).compile().memory_analysis()``:
argument + output + temp − donated-alias bytes), checked against an
enforced budget BEFORE anything is allocated, so the dense arm's
endpoint is a captured ``MemoryBudgetExceeded`` record — never a host
crash.  The budget is set between the dense arm's 4×- and 8×-base
requirements, so dense deterministically tops out at 4×base while the
sketched arm keeps doubling.

Output (``experiments/bench/extreme_scale.json``): per-arm steps/s-vs-
batch and peak-bytes-vs-batch trajectories, each arm's max surviving
batch + endpoint reason, and the resulting max-batch ratio.
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.data import ExtremeStream
from repro.train.extreme import MachConfig, make_extreme_step, plan_extreme


class MemoryBudgetExceeded(RuntimeError):
    """The compiled step's measured requirement exceeds the enforced
    budget — raised BEFORE allocation, so the sweep records a memory
    failure instead of taking the host down."""

    def __init__(self, required: int, budget: int):
        super().__init__(f"compiled step needs {required:,} B "
                         f"> memory budget {budget:,} B")
        self.required = int(required)
        self.budget = int(budget)


# what a real allocator failure looks like, per backend (the enforced
# budget should always fire first — these are the safety net)
_OOM_MARKERS = ("RESOURCE_EXHAUSTED", "Out of memory", "out of memory",
                "OOM", "failed to allocate")


def is_oom_error(exc: BaseException) -> bool:
    if isinstance(exc, (MemoryBudgetExceeded, MemoryError)):
        return True
    return any(m in str(exc) for m in _OOM_MARKERS)


def capture_memory_failure(fn: Callable):
    """Run ``fn()``; return ``(result, None)`` on success or ``(None,
    record)`` when it dies of a memory-class error.  Anything else
    propagates — only memory exhaustion is a *recorded outcome*."""
    try:
        return fn(), None
    except Exception as e:  # noqa: BLE001 — filtered by is_oom_error
        if not is_oom_error(e):
            raise
        rec = {"error": type(e).__name__, "message": str(e)[:500]}
        if isinstance(e, MemoryBudgetExceeded):
            rec["required_bytes"] = e.required
            rec["budget_bytes"] = e.budget
        return None, rec


def compiled_step_bytes(jit_fn, *abstract_args) -> int:
    """The compiled step's measured memory requirement in bytes —
    argument + output + temp − alias (donated buffers) — from XLA's own
    accounting.  No allocation happens: the args are ShapeDtypeStructs."""
    ma = jit_fn.lower(*abstract_args).compile().memory_analysis()
    if ma is None:
        return 0
    return int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)


def _batch_template(cfg: MachConfig, batch: int) -> Dict:
    return {
        "features": jax.ShapeDtypeStruct((batch, cfg.nnz), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "negatives": jax.ShapeDtypeStruct((cfg.n_negatives,), jnp.int32),
    }


def _build(cfg: MachConfig, optimizer: str, plan, lr: float,
           backend: Optional[str]):
    init_fn, step_fn, opts = make_extreme_step(
        cfg, optimizer=optimizer, lr=lr, plan=plan, backend=backend)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    params_sds = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    opt_sds = {p: jax.eval_shape(o.init) for p, o in opts.items()}
    return init_fn, opts, jstep, params_sds, opt_sds


def measure_required_bytes(cfg: MachConfig, optimizer: str, plan,
                           batch: int, *, lr: float = 1e-2,
                           backend: Optional[str] = None) -> int:
    """One arm's measured step requirement at ``batch`` — used both to
    derive the enforced budget and as each sweep point's peak-bytes."""
    _, _, jstep, params_sds, opt_sds = _build(cfg, optimizer, plan, lr,
                                              backend)
    return compiled_step_bytes(jstep, params_sds, opt_sds,
                               _batch_template(cfg, batch))


def _attempt(cfg: MachConfig, optimizer: str, plan, batch: int, *,
             mem_budget: Optional[int], steps: int, lr: float,
             backend: Optional[str], cmap: np.ndarray) -> Dict:
    """One sweep point: measure the compiled requirement, enforce the
    budget (raising ``MemoryBudgetExceeded`` pre-allocation), then run
    ``steps`` timed steps and report the throughput."""
    init_fn, opts, jstep, params_sds, opt_sds = _build(
        cfg, optimizer, plan, lr, backend)
    tpl = _batch_template(cfg, batch)
    required = compiled_step_bytes(jstep, params_sds, opt_sds, tpl)
    if mem_budget is not None and required > mem_budget:
        raise MemoryBudgetExceeded(required, mem_budget)

    params = init_fn(jax.random.PRNGKey(cfg.seed))
    opt_state = {p: o.init() for p, o in opts.items()}
    stream = ExtremeStream(cfg.data_config(batch))

    def host_batch(i):
        b = stream.batch(i)
        return {"features": jnp.asarray(b["features"]),
                "labels": jnp.asarray(cmap[b["labels"]], jnp.int32),
                "negatives": jnp.asarray(cmap[b["negatives"]], jnp.int32)}

    params, opt_state, m = jstep(params, opt_state, host_batch(0))  # warmup
    jax.block_until_ready(m["loss"])
    losses = []
    t0 = time.perf_counter()
    for i in range(1, steps + 1):
        params, opt_state, m = jstep(params, opt_state, host_batch(i))
        losses.append(m["loss"])
    jax.block_until_ready(losses[-1])
    wall = time.perf_counter() - t0
    return {
        "peak_bytes": required,
        "steps_per_s": steps / wall,
        "examples_per_s": steps * batch / wall,
        "final_loss": float(losses[-1]),
    }


def sweep_arm(attempt: Callable[[int], Dict], *, base_batch: int,
              max_doublings: int) -> Dict:
    """Double the batch from ``base_batch``; every successful attempt
    becomes a trajectory point, the first memory-class failure ends the
    sweep as a RECORDED endpoint.  ``attempt(batch)`` returns a point
    dict or raises (``capture_memory_failure`` decides what counts)."""
    points, failure = [], None
    batch = base_batch
    for _ in range(max_doublings + 1):
        result, fail = capture_memory_failure(lambda: attempt(batch))
        if fail is not None:
            failure = dict(fail, batch=batch)
            break
        points.append(dict(result, batch=batch))
        batch *= 2
    return {
        "points": points,
        "failure": failure,
        "max_ok_batch": points[-1]["batch"] if points else 0,
        "endpoint": "memory_failure" if failure is not None else "sweep_cap",
    }


def run(quick: bool = False, backend: Optional[str] = None):
    if quick:
        cfg = MachConfig(n_classes=200_000, n_meta=32_768, n_features=4096,
                         dim=16, nnz=8, n_negatives=256)
        base_batch, max_doublings, steps, aux_budget = 128, 3, 2, "0.1x"
    else:
        cfg = MachConfig(n_classes=8_000_000, n_meta=1 << 21,
                         n_features=1 << 16, dim=64, nnz=16,
                         n_negatives=1024)
        base_batch, max_doublings, steps, aux_budget = 1024, 6, 3, "0.05x"
    lr = 1e-2
    plan = plan_extreme(cfg, aux_budget, optimizer="cs_rmsprop",
                        backend=backend)
    cmap = cfg.class_maps()[0]   # the sweep measures one replica

    # The enforced budget sits between the dense arm's 4×- and 8×-base
    # requirements: 4×base provably fits, 8×base provably does not — the
    # dense endpoint is deterministic and the headroom the sketch frees
    # (its m/v buffers) goes to the sketched arm's extra doublings.
    lo = measure_required_bytes(cfg, "dense_adam", None, base_batch * 4,
                                lr=lr)
    hi = measure_required_bytes(cfg, "dense_adam", None, base_batch * 8,
                                lr=lr)
    mem_budget = (lo + hi) // 2
    print(f"[extreme_scale] dense requires {lo:,} B at {base_batch * 4} / "
          f"{hi:,} B at {base_batch * 8}; budget {mem_budget:,} B",
          flush=True)

    arms = {}
    for name, optimizer, arm_plan in [("dense_adam", "dense_adam", None),
                                      ("cs_rmsprop", "cs_rmsprop", plan)]:
        def attempt(batch, _opt=optimizer, _plan=arm_plan):
            return _attempt(cfg, _opt, _plan, batch, mem_budget=mem_budget,
                            steps=steps, lr=lr, backend=backend, cmap=cmap)
        arms[name] = sweep_arm(attempt, base_batch=base_batch,
                               max_doublings=max_doublings)
        a = arms[name]
        print(f"[extreme_scale] {name}: max_ok_batch={a['max_ok_batch']} "
              f"endpoint={a['endpoint']} "
              f"({len(a['points'])} points)", flush=True)

    dense, sketch = arms["dense_adam"], arms["cs_rmsprop"]
    out = {
        "config": {
            "n_classes": cfg.n_classes, "n_meta": cfg.n_meta,
            "n_features": cfg.n_features, "dim": cfg.dim, "nnz": cfg.nnz,
            "n_negatives": cfg.n_negatives, "base_batch": base_batch,
            "max_doublings": max_doublings, "timed_steps": steps,
            "aux_budget": aux_budget, "quick": quick,
        },
        "mem_budget_bytes": mem_budget,
        "plan_predicted_aux_bytes": plan.predicted_aux_bytes,
        "arms": arms,
        "steps_per_s_vs_batch": {
            n: [[p["batch"], p["steps_per_s"]] for p in a["points"]]
            for n, a in arms.items()},
        "peak_bytes_vs_batch": {
            n: [[p["batch"], p["peak_bytes"]] for p in a["points"]]
            for n, a in arms.items()},
        "max_batch_ratio": (sketch["max_ok_batch"]
                            / max(dense["max_ok_batch"], 1)),
    }
    save_result("extreme_scale", out)
    return {
        "dense_max_batch": dense["max_ok_batch"],
        "dense_endpoint": dense["endpoint"],
        "sketch_max_batch": sketch["max_ok_batch"],
        "sketch_endpoint": sketch["endpoint"],
        "max_batch_ratio": out["max_batch_ratio"],
        "mem_budget_MB": mem_budget / 2**20,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-safe scale (32k-row meta table)")
    ap.add_argument("--store-backend", default=None,
                    help="kernel backend for the sketched arm ('ref' | "
                         "'xla' | 'tiled' | 'interpret' | 'auto')")
    a = ap.parse_args()
    print(run(quick=a.quick, backend=a.store_backend))
