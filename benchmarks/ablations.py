"""Paper-aligned ablations beyond the headline tables.

1. **Graceful degradation** (paper §5 "graceful memory trade-off", Q4/Q5
   of §7): sweep sketch compression 2–50× for CS-Adam and record test
   perplexity + aux bytes — the central claim that accuracy degrades
   smoothly as the sketch shrinks.
2. **Canonical vs strict-paper semantics**: our batched canonical step
   (query pre-update sketch, est = est_old + Δ — one less sketch pass)
   vs the paper's exact 3-pass per-item order.  Claim: statistically
   indistinguishable convergence.
3. **Hokusai fold mid-training** (paper §5): halve the sketch width at
   step T/2 and keep training — accumulated state is preserved, no loss
   spike, memory halves.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result, small_lm_cfg, strip_arrays, \
    train_small_lm
from repro.core import optimizers as O
from repro.core import sketch as cs
from repro.core.partition import SketchPolicy
from repro.data import ZipfLM, ZipfLMConfig
from repro.models import transformer as tf

POL = SketchPolicy(min_rows=512)


def sweep_compression(steps: int):
    """CS-MV to 10×, β₁=0 CMS beyond — mirroring the paper's own usage
    (CS-MV at 5× for LMs, β₁=0 at 100× for the extreme task).  Measured
    finding: the sketched 1st moment's median-noise destabilizes CS-MV
    beyond ~10× (ppl diverges; cleaning does NOT rescue it — the noise is
    in m, not the CMS over-estimate), while the moment-free optimizer
    degrades gracefully to 50×+.  One diverging CS-MV point is kept to
    document the boundary."""
    cfg = small_lm_cfg(vocab=8192)
    rows = {}
    base = train_small_lm(O.adam(1e-3), cfg=cfg, steps=steps)
    rows["dense"] = {"ppl": base["final_ppl"],
                     "aux_bytes": base["opt_state_bytes"]}
    for comp in (2.0, 5.0, 10.0):
        hp = O.SketchHParams(compression=comp, width_multiple=16)
        r = train_small_lm(O.countsketch_adam(1e-3, policy=POL, hparams=hp),
                           cfg=cfg, steps=steps)
        rows[f"cs_mv_{comp:g}x"] = {"ppl": r["final_ppl"],
                                    "aux_bytes": r["opt_state_bytes"]}
    hp20 = O.SketchHParams(compression=20.0, width_multiple=16)
    r = train_small_lm(O.countsketch_adam(1e-3, policy=POL, hparams=hp20),
                       cfg=cfg, steps=steps)
    rows["cs_mv_20x_BOUNDARY"] = {"ppl": r["final_ppl"],
                                  "aux_bytes": r["opt_state_bytes"]}
    for comp in (20.0, 50.0):
        hp = O.SketchHParams(compression=comp, width_multiple=16)
        r = train_small_lm(
            O.countsketch_rmsprop(1e-3, policy=POL, hparams=hp),
            cfg=cfg, steps=steps)
        rows[f"cs_b1_0_{comp:g}x"] = {"ppl": r["final_ppl"],
                                      "aux_bytes": r["opt_state_bytes"]}
    return rows


def strict_vs_canonical(steps: int):
    cfg = small_lm_cfg(vocab=4096)
    out = {}
    for name, strict in (("canonical", False), ("strict_paper", True)):
        hp = O.SketchHParams(compression=5.0, width_multiple=16,
                             strict_paper=strict,
                             dense_chunk=0 if strict else 8192)
        r = train_small_lm(O.countsketch_adam(1e-3, policy=POL, hparams=hp),
                           cfg=cfg, steps=steps)
        out[name] = {"ppl": r["final_ppl"],
                     "steps_per_s": round(r["steps_per_s"], 2)}
    return out


def fold_mid_training(steps: int):
    """Train CS-Adam, Hokusai-fold the sketches at steps//2, continue."""
    cfg = small_lm_cfg(vocab=4096)
    hp1 = O.SketchHParams(compression=5.0, width_multiple=32)
    hp2 = O.SketchHParams(compression=10.0, width_multiple=16)
    opt1 = O.countsketch_adam(1e-3, policy=POL, hparams=hp1)
    opt2 = O.countsketch_adam(1e-3, policy=POL, hparams=hp2)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    data = ZipfLM(ZipfLMConfig(vocab_size=cfg.vocab_size, seq_len=64,
                               global_batch=8))

    def make_step(opt):
        @jax.jit
        def step(params, st, tokens, labels):
            def loss_fn(p):
                return tf.train_loss(cfg, p, {"tokens": tokens,
                                              "labels": labels}, remat=False)
            l, g = jax.value_and_grad(loss_fn)(params)
            u, st = opt.update(O.clip_by_global_norm(1.0)(g), st, params)
            return O.apply_updates(params, u), st, l
        return step

    st = opt1.init(params)
    step1, step2 = make_step(opt1), make_step(opt2)
    losses = []
    half = steps // 2
    bytes_before = O.state_bytes(st)
    for i in range(half):
        b = data.batch(i)
        params, st, l = step1(params, st, jnp.asarray(b["tokens"]),
                              jnp.asarray(b["labels"]))
        losses.append(float(l))
    # Hokusai fold every sketch leaf (width halves, state preserved)
    from repro.checkpoint import store
    st = store.fold_sketches(st, store.default_is_sketch)
    bytes_after = O.state_bytes(st)
    for i in range(half, steps):
        b = data.batch(i)
        params, st, l = step2(params, st, jnp.asarray(b["tokens"]),
                              jnp.asarray(b["labels"]))
        losses.append(float(l))
    pre = float(np.mean(losses[half - 10:half]))
    post = float(np.mean(losses[half:half + 10]))
    return {
        "loss_before_fold": pre,
        "loss_after_fold": post,
        "fold_spike": post - pre,
        "bytes_before": bytes_before,
        "bytes_after": bytes_after,
        "final_loss": float(np.mean(losses[-10:])),
    }


def run(quick: bool = False):
    steps = 120 if quick else 300
    out = {
        "compression_sweep": sweep_compression(steps),
        "strict_vs_canonical": strict_vs_canonical(steps),
        "fold_mid_training": fold_mid_training(steps),
    }
    save_result("ablations", out)
    summary = {
        "sweep": {k: round(v["ppl"], 1)
                  for k, v in out["compression_sweep"].items()},
        "strict_vs_canonical": out["strict_vs_canonical"],
        "fold_spike": round(out["fold_mid_training"]["fold_spike"], 3),
    }
    return summary


if __name__ == "__main__":
    print(run())
