"""Paper Tables 3/4 (Wikitext-2 perplexity) + Table 7 (convergence
trajectory) at CPU scale.

Protocol: identical small LM + zipf stream; optimizers compared with the
paper's groupings:

  Momentum table (Tab 3):   Momentum | CS-Momentum | LR-NMF(-invalid)
  Adam table (Tab 4/7):     Adam | CS-MV | CS-V | LR-NMF-V

CS sketches the embedding + lm_head aux state at 5× compression (the
paper's LM setting).  Eval perplexity on a held-out stream every 50 steps
gives the Tab-7-style trajectory.
"""
from __future__ import annotations

from benchmarks.common import save_result, strip_arrays, train_small_lm
from repro.core import lowrank, optimizers as O
from repro.core.partition import SketchPolicy

POL = SketchPolicy(min_rows=512)
HP = O.SketchHParams(compression=5.0, width_multiple=16)


def run(quick: bool = False):
    from benchmarks.common import small_lm_cfg
    steps = 200 if quick else 500
    # vocab 8192 ≈ the paper's collision regime (~14 rows/bucket at 5x)
    # with hot-row mass spread over more buckets than the 2k default
    kw = dict(cfg=small_lm_cfg(vocab=8192), steps=steps, eval_every=50)
    rows = {}

    # --- Adam family (paper Tab. 4 / 7) -----------------------------------
    rows["adam"] = train_small_lm(O.adam(1e-3), **kw)
    rows["cs_mv"] = train_small_lm(
        O.countsketch_adam(1e-3, policy=POL, hparams=HP), **kw)
    rows["cs_v"] = train_small_lm(
        O.countsketch_adam(1e-3, policy=POL, hparams=HP,
                           sketch_first_moment=False), **kw)
    rows["lr_nmf_v"] = train_small_lm(
        lowrank.nmf_rank1_adam(1e-3, policy=POL), **kw)

    # --- Momentum family (paper Tab. 3) ------------------------------------
    rows["momentum"] = train_small_lm(O.momentum(0.5), **kw)
    rows["cs_momentum"] = train_small_lm(
        O.countsketch_momentum(0.5, policy=POL, hparams=HP), **kw)

    out = {k: strip_arrays(v) for k, v in rows.items()}
    for k in out:
        out[k]["aux_bytes_vs_adam"] = (
            out[k]["opt_state_bytes"] / out["adam"]["opt_state_bytes"])
    save_result("small_lm", out)
    return {k: {"ppl": v["final_ppl"],
                "bytes_ratio": round(v["aux_bytes_vs_adam"], 3)}
            for k, v in out.items()}


if __name__ == "__main__":
    print(run())
