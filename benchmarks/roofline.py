"""Reads the dry-run artifacts (experiments/dryrun/**.json) and renders
the §Roofline table for EXPERIMENTS.md: the three terms per (arch ×
shape × mesh), the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and the
perfect-overlap MFU bound.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh: str = "single", tag: str = "") -> List[Dict]:
    rows = []
    for p in sorted((DRYRUN / mesh).glob("*.json")):
        rec = json.loads(p.read_text())
        is_tagged = "__" in p.stem.replace(
            f"{rec.get('arch', '')}__{rec.get('shape', '')}", "")
        if tag:
            if not p.stem.endswith(f"__{tag}"):
                continue
        elif p.stem.count("__") > 1:
            continue
        rows.append(rec)
    return rows


def fmt_row(rec: Dict) -> Optional[str]:
    if rec.get("status") == "skipped":
        return (f"| {rec['arch']} | {rec['shape']} | — | — | — | skip | — | — "
                f"| {rec['reason'][:44]} |")
    if rec.get("status") != "ok":
        return (f"| {rec['arch']} | {rec['shape']} | — | — | — | ERROR | — "
                f"| — | {rec.get('error', '')[:44]} |")
    r = rec["roofline"]
    mem = (rec.get("memory") or {})
    peak = mem.get("peak_bytes_per_device", 0) / 2**30
    return ("| {arch} | {shape} | {c:.2e} | {m:.2e} | {n:.2e} | {dom} | "
            "{mfu:.3f} | {ratio:.2f} | {peak:.1f} GiB |").format(
        arch=rec["arch"], shape=rec["shape"], c=r["compute_s"],
        m=r["memory_s"], n=r["collective_s"], dom=r["dominant"],
        mfu=r["mfu_bound"], ratio=r["useful_flops_ratio"], peak=peak)


HEADER = ("| arch | shape | compute s | memory s | collective s | dominant "
          "| MFU≤ | useful/HLO | peak/dev |\n"
          "|---|---|---|---|---|---|---|---|---|")


def table(mesh: str = "single", tag: str = "") -> str:
    lines = [HEADER]
    for rec in load(mesh, tag):
        line = fmt_row(rec)
        if line:
            lines.append(line)
    return "\n".join(lines)


def run(quick: bool = False):
    out = {}
    for mesh in ("single", "multi"):
        if (DRYRUN / mesh).exists():
            rows = load(mesh)
            ok = [r for r in rows if r.get("status") == "ok"]
            out[mesh] = {
                "cells_ok": len(ok),
                "cells_total": len(rows),
                "dominant_counts": {
                    d: sum(1 for r in ok
                           if r["roofline"]["dominant"] == d)
                    for d in ("compute", "memory", "collective")},
            }
            print(f"\n=== {mesh} mesh ===")
            print(table(mesh))
    from benchmarks.common import save_result
    save_result("roofline_summary", out)
    return out


if __name__ == "__main__":
    print(run())
