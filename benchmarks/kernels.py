"""Sketch-kernel micro-benchmarks: µs/call of the jnp reference path on
CPU (what actually executes here) + the analytical bytes-moved model for
the Pallas TPU kernels (what executes on the target).

The fused-Adam traffic model is the DESIGN.md §3 argument in numbers:
    unfused  = 4 sketch traversals / moment  (query, update ×2 reads+write)
    fused    = 1 HBM round trip per depth row

Backend axis (DESIGN.md §10): ``--backend <name|all>`` times the
sparse-rows CS-Adam step through each registered kernel backend
(ref | stream | tiled | interpret) on a duplicate-heavy id batch, so the
stream-vs-tiled crossover is *measured*, not asserted.  Off-TPU the
Pallas backends run in interpret mode — their absolute numbers are
Python-interpreter timings, only the grid-step counts (k for stream,
k/TILE for tiled) transfer to hardware; the traffic model supplies the
projected ratio.

    PYTHONPATH=src python benchmarks/kernels.py                 # ref only
    PYTHONPATH=src python benchmarks/kernels.py --backend all
    PYTHONPATH=src python benchmarks/kernels.py --backend tiled
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import save_result
except ModuleNotFoundError:  # invoked as `python benchmarks/kernels.py`
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import save_result
from repro import kernels as K
from repro.core import sketch as cs
from repro.core.hashing import HashFamily
from repro.kernels import ops, ref
from repro.kernels.cs_adam_tiled import DEFAULT_TILE


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def traffic_model(depth, width, dim, k, dtype_bytes=4):
    """Bytes through HBM per op on TPU (whole rows, VMEM-tiled)."""
    row = dim * dtype_bytes
    return {
        "query": depth * k * row,                   # read k rows per depth
        "update": 2 * depth * k * row,              # RMW per depth row
        "adam_unfused": (3 + 3 + 2) * depth * k * row * 2,  # m & v, 3-pass
        "adam_fused": 2 * 2 * depth * k * row,      # one RMW per sketch
    }


def _adam_backend_rows(backends: List[str], *, depth=3, width=256, dim=128,
                       k=64, dup_frac=0.5, iters=3):
    """Time the sparse-rows CS-Adam step per backend on one batch shape.

    ``dup_frac`` of the ids are duplicates (drawn from a small pool) —
    the regime the dedup pre-pass targets.
    """
    n = 4096
    spec_m = cs.for_param((n, dim), compression=4.0, depth=depth,
                          signed=True, seed=1, width_multiple=16)
    spec_v = cs.for_param((n, dim), compression=4.0, depth=depth,
                          signed=False, seed=2, width_multiple=16)
    M, V = cs.init(spec_m), cs.init(spec_v)
    rng = np.random.RandomState(0)
    n_dup = int(k * dup_frac)
    ids = np.concatenate([rng.randint(0, n, k - n_dup),
                          rng.randint(0, 8, n_dup)])  # hot duplicate pool
    ids = jnp.asarray(rng.permutation(ids), jnp.int32)
    g = jnp.asarray(rng.randn(k, dim), jnp.float32)
    step = jnp.asarray(1, jnp.int32)

    rows = []
    for name in backends:
        fn = jax.jit(lambda M, V, ids, g, step, _b=name: K.adam_rows(
            spec_m, spec_v, M, V, ids, g, step, lr=1e-3, backend=_b))
        us = _time(fn, M, V, ids, g, step, iters=iters)
        # items processed per sequential step: per-item for ref/stream,
        # per-tile for the tiled kernels, the whole batch at once for xla
        grid_steps = {"ref": k, "stream": k,
                      "xla": 1}.get(name, -(-k // DEFAULT_TILE))
        rows.append({"backend": name, "k": k, "dim": dim, "depth": depth,
                     "dup_frac": dup_frac, "us_per_step_cpu": round(us, 1),
                     "grid_steps": grid_steps})
        print(f"  adam[{name:9s}] k={k:4d} dup={dup_frac:.1f} "
              f"{us:10.1f} µs/step  (grid steps: {grid_steps})")
    return rows


def run(quick: bool = False, backend: Optional[str] = None):
    shapes = [(3, 1024, 256, 128), (3, 4096, 512, 1024)]
    if quick:
        shapes = shapes[:1]
    results = []
    for depth, width, dim, k in shapes:
        spec = cs.SketchSpec(depth=depth, width=width, dim=dim, seed=0)
        S = cs.init(spec)
        ids = jnp.asarray(np.random.RandomState(0).randint(0, 10 * width,
                                                           size=k), jnp.int32)
        delta = jax.random.normal(jax.random.PRNGKey(0), (k, dim))

        q = jax.jit(lambda S, i: ops.sketch_query(spec, S, i))
        u = jax.jit(lambda S, i, d: ops.sketch_update(spec, S, i, d))
        tm = traffic_model(depth, width, dim, k)
        results.append({
            "shape": {"depth": depth, "width": width, "dim": dim, "k": k},
            "query_us_cpu": _time(q, S, ids),
            "update_us_cpu": _time(u, S, ids, delta),
            "traffic_bytes": tm,
            "fused_traffic_saving":
                round(tm["adam_unfused"] / tm["adam_fused"], 2),
        })

    # ---- backend axis ------------------------------------------------------
    if backend is None:
        names = ["ref"]               # default: the fast-on-CPU oracle only
    elif backend == "all":
        names = list(K.backends())
    else:
        names = [K.resolve_backend(backend)]
    # interpret-mode Pallas on CPU is slow — shrink the batch there
    pallas_names = {"stream", "tiled", "interpret"}
    small = jax.default_backend() != "tpu" and bool(pallas_names & set(names))
    adam_rows = _adam_backend_rows(
        names, k=16 if small else 64, dim=128, iters=1 if small else 10)

    save_result("kernels", {"rows": results, "adam_backends": adam_rows})
    return ([{**r["shape"], "query_us": round(r["query_us_cpu"], 1),
              "fused_saving": r["fused_traffic_saving"]} for r in results]
            + [{k_: r[k_] for k_ in ("backend", "us_per_step_cpu",
                                     "grid_steps")} for r in adam_rows])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="kernel backend to time (ref|xla|stream|tiled|"
                         "interpret|all); default ref")
    args = ap.parse_args()
    print(run(quick=args.quick, backend=args.backend))
