"""Sketch-kernel micro-benchmarks: µs/call of the jnp reference path on
CPU (what actually executes here) + the analytical bytes-moved model for
the Pallas TPU kernels (what executes on the target).

The fused-Adam traffic model is the DESIGN.md §3 argument in numbers:
    unfused  = 4 sketch traversals / moment  (query, update ×2 reads+write)
    fused    = 1 HBM round trip per depth row
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core import sketch as cs
from repro.core.hashing import HashFamily
from repro.kernels import ops, ref


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def traffic_model(depth, width, dim, k, dtype_bytes=4):
    """Bytes through HBM per op on TPU (whole rows, VMEM-tiled)."""
    row = dim * dtype_bytes
    return {
        "query": depth * k * row,                   # read k rows per depth
        "update": 2 * depth * k * row,              # RMW per depth row
        "adam_unfused": (3 + 3 + 2) * depth * k * row * 2,  # m & v, 3-pass
        "adam_fused": 2 * 2 * depth * k * row,      # one RMW per sketch
    }


def run(quick: bool = False):
    shapes = [(3, 1024, 256, 128), (3, 4096, 512, 1024)]
    if quick:
        shapes = shapes[:1]
    results = []
    for depth, width, dim, k in shapes:
        spec = cs.SketchSpec(depth=depth, width=width, dim=dim, seed=0)
        S = cs.init(spec)
        ids = jnp.asarray(np.random.RandomState(0).randint(0, 10 * width,
                                                           size=k), jnp.int32)
        delta = jax.random.normal(jax.random.PRNGKey(0), (k, dim))

        q = jax.jit(lambda S, i: ops.sketch_query(spec, S, i))
        u = jax.jit(lambda S, i, d: ops.sketch_update(spec, S, i, d))
        tm = traffic_model(depth, width, dim, k)
        results.append({
            "shape": {"depth": depth, "width": width, "dim": dim, "k": k},
            "query_us_cpu": _time(q, S, ids),
            "update_us_cpu": _time(u, S, ids, delta),
            "traffic_bytes": tm,
            "fused_traffic_saving":
                round(tm["adam_unfused"] / tm["adam_fused"], 2),
        })
    save_result("kernels", {"rows": results})
    return [{**r["shape"], "query_us": round(r["query_us_cpu"], 1),
             "fused_saving": r["fused_traffic_saving"]} for r in results]


if __name__ == "__main__":
    print(run())
