"""Paper Fig. 5: effect of Count-Min-Sketch cleaning on convergence.

Protocol (MegaFace protocol at CPU scale): a softmax classifier over a
zipf-distributed class set trained with CS-Adam and CS-Adagrad, sketches
at 20% size, comparing cleaning (α, every-C) against no cleaning and the
dense baseline.  Reports final eval accuracy + the 2nd-moment ℓ2 error.

Built on the chain/AuxStore transforms (DESIGN.md §14): explicit
``CountSketchStore``/``CountMinStore`` pairs selected by a
``StoreTree``, the cleaning schedule attached to the count-min store.
The ``cs_adam_clean_async`` arm runs the SAME schedule in ``async`` mode
— the in-graph hook is an identity and an ``AsyncCleaner`` dispatches
the decay between steps (DESIGN.md §18) — and the A/B records its final
parameters' max |Δ| vs the sync arm, which device dataflow ordering
pins at 0.0 (bit-identical placement, off-critical-path cost).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core import optimizers as O
from repro.core import sketch as cs
from repro.core.cleaning import AsyncCleaner, CleaningSchedule
from repro.core.stores import CountMinStore, CountSketchStore, StoreTree

HP = O.SketchHParams(compression=5.0, width_multiple=16)


def _make_problem(n_classes=4096, d=32, seed=0):
    key = jax.random.PRNGKey(seed)
    class_emb = jax.random.normal(key, (n_classes, d))
    zipf = np.arange(1, n_classes + 1) ** -1.1
    zipf /= zipf.sum()

    def batch(step, bs=64):
        rng = np.random.RandomState(step * 7919 % (2**31 - 1))
        y = rng.choice(n_classes, size=bs, p=zipf)
        x = np.asarray(class_emb[y]) + 0.5 * rng.randn(bs, d)
        return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)

    return batch, n_classes, d


def _specs(n_classes, d):
    return (HP.spec("class_head/table", (n_classes, d), signed=True),
            HP.spec("class_head/table", (n_classes, d), signed=False))


def _tree(n_classes, d, *, cleaning=None, first_moment=True):
    """The explicit store pair for the class-head table: CS 1st moment
    (signed, median), CM 2nd moment (min, optional cleaning)."""
    mspec, vspec = _specs(n_classes, d)
    return StoreTree.select(
        m=CountSketchStore(spec=mspec) if first_moment else None,
        v=CountMinStore(spec=vspec, cleaning=cleaning),
        where=lambda p, s: s == (n_classes, d))


def _cs_adam(lr, n_classes, d, cleaning=None):
    return O.adam_from_stores(lr, _tree(n_classes, d, cleaning=cleaning))


def _cs_adagrad(lr, n_classes, d, cleaning=None):
    tree = _tree(n_classes, d, cleaning=cleaning, first_moment=False)
    return O.adagrad_from_stores(lr, tree)


def _train(opt, steps, batch_fn, n_classes, d, track_v_error=False,
           cleaner: AsyncCleaner = None):
    params = {"class_head": {"table": jnp.zeros((n_classes, d))}}
    st = opt.init(params)
    v_exact = jnp.zeros((n_classes, d))
    b2 = 0.999
    v_errs = []
    _, vspec = _specs(n_classes, d)

    @jax.jit
    def step(params, st, x, y):
        def loss(p):
            logits = x @ p["class_head"]["table"].T
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)
        l, g = jax.value_and_grad(loss)(params)
        u, st = opt.update(g, st, params)
        return O.apply_updates(params, u), st, l, g

    for i in range(steps):
        if cleaner is not None:
            # dispatch BEFORE the step that will observe counter i+1 —
            # the boundary sync's in-graph lax.cond keys on
            st, _ = cleaner.maybe_dispatch(st, i + 1)
        x, y = batch_fn(i)
        params, st, l, g = step(params, st, x, y)
        if track_v_error and i % 20 == 0:
            gg = g["class_head"]["table"]
            v_exact = b2 * v_exact + (1 - b2) * gg * gg
            vleaf = st["v"]["class_head"]["table"]
            est = cs.query_dense(vspec, vleaf, n_classes)
            v_errs.append(float(jnp.linalg.norm(est - v_exact) /
                                jnp.maximum(jnp.linalg.norm(v_exact),
                                            1e-9)))
    # eval accuracy on fresh batches
    correct = total = 0
    for j in range(10):
        x, y = batch_fn(10_000 + j)
        pred = jnp.argmax(x @ params["class_head"]["table"].T, axis=-1)
        correct += int((pred == y).sum())
        total += y.shape[0]
    return {"accuracy": correct / total, "v_rel_error": v_errs,
            "params": params}


def run(quick: bool = False):
    steps = 200 if quick else 600
    batch_fn, n_classes, d = _make_problem()
    clean = CleaningSchedule(alpha=0.2, every=125)
    aclean = CleaningSchedule(alpha=0.2, every=125, mode="async")
    acleaner = AsyncCleaner(aclean)
    out = {}
    for name, opt, track, cleaner in [
        ("adam_dense", O.adam(0.05), False, None),
        ("cs_adam_noclean", _cs_adam(0.05, n_classes, d), True, None),
        ("cs_adam_clean",
         _cs_adam(0.05, n_classes, d, cleaning=clean), True, None),
        ("cs_adam_clean_async",
         _cs_adam(0.05, n_classes, d, cleaning=aclean), True, acleaner),
        ("adagrad_dense", O.adagrad(0.5), False, None),
        ("cs_adagrad_noclean",
         _cs_adagrad(0.5, n_classes, d), True, None),
        ("cs_adagrad_clean",
         _cs_adagrad(0.5, n_classes, d,
                     cleaning=CleaningSchedule(alpha=0.5, every=125)),
         True, None),
    ]:
        out[name] = _train(opt, steps, batch_fn, n_classes, d,
                           track_v_error=track, cleaner=cleaner)
    # async-vs-sync A/B: same schedule, decay moved between steps —
    # device dataflow ordering keeps the numerics bit-identical
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        out["cs_adam_clean"]["params"], out["cs_adam_clean_async"]["params"])
    async_max_dev = max(jax.tree_util.tree_leaves(diff))
    for v in out.values():
        v.pop("params")
    out["async_vs_sync_max_abs_param_diff"] = async_max_dev
    out["async_cleans_dispatched"] = acleaner.dispatched
    save_result("cleaning", out)
    return {k: round(v["accuracy"], 4) for k, v in out.items()
            if isinstance(v, dict)}


if __name__ == "__main__":
    print(run())
