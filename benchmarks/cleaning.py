"""Paper Fig. 5: effect of Count-Min-Sketch cleaning on convergence.

Protocol (MegaFace protocol at CPU scale): a softmax classifier over a
zipf-distributed class set trained with CS-Adam and CS-Adagrad, sketches
at 20% size, comparing cleaning (α, every-C) against no cleaning and the
dense baseline.  Reports final eval accuracy + the 2nd-moment ℓ2 error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core import optimizers as O
from repro.core.cleaning import CleaningSchedule
from repro.core.partition import SketchPolicy

POL = SketchPolicy(min_rows=512)
HP = O.SketchHParams(compression=5.0, width_multiple=16)


def _make_problem(n_classes=4096, d=32, seed=0):
    key = jax.random.PRNGKey(seed)
    class_emb = jax.random.normal(key, (n_classes, d))
    zipf = np.arange(1, n_classes + 1) ** -1.1
    zipf /= zipf.sum()

    def batch(step, bs=64):
        rng = np.random.RandomState(step * 7919 % (2**31 - 1))
        y = rng.choice(n_classes, size=bs, p=zipf)
        x = np.asarray(class_emb[y]) + 0.5 * rng.randn(bs, d)
        return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)

    return batch, n_classes, d


def _train(opt, steps, batch_fn, n_classes, d, track_v_error=False):
    params = {"class_head": {"table": jnp.zeros((n_classes, d))}}
    st = opt.init(params)
    v_exact = jnp.zeros((n_classes, d))
    b2 = 0.999
    v_errs = []

    @jax.jit
    def step(params, st, x, y):
        def loss(p):
            logits = x @ p["class_head"]["table"].T
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)
        l, g = jax.value_and_grad(loss)(params)
        u, st = opt.update(g, st, params)
        return O.apply_updates(params, u), st, l, g

    for i in range(steps):
        x, y = batch_fn(i)
        params, st, l, g = step(params, st, x, y)
        if track_v_error and i % 20 == 0:
            gg = g["class_head"]["table"]
            v_exact = b2 * v_exact + (1 - b2) * gg * gg
            vleaf = st["v"]["class_head"]["table"]
            if vleaf.ndim == 3:
                from repro.core import sketch as cs
                spec = HP.spec("class_head/table", (n_classes, d),
                               signed=False)
                est = cs.query_dense(spec, vleaf, n_classes)
                v_errs.append(float(jnp.linalg.norm(est - v_exact) /
                                    jnp.maximum(jnp.linalg.norm(v_exact),
                                                1e-9)))
    # eval accuracy on fresh batches
    correct = total = 0
    for j in range(10):
        x, y = batch_fn(10_000 + j)
        pred = jnp.argmax(x @ params["class_head"]["table"].T, axis=-1)
        correct += int((pred == y).sum())
        total += y.shape[0]
    return {"accuracy": correct / total, "v_rel_error": v_errs}


def run(quick: bool = False):
    steps = 200 if quick else 600
    batch_fn, n_classes, d = _make_problem()
    out = {}
    clean = CleaningSchedule(alpha=0.2, every=125)
    for name, opt, track in [
        ("adam_dense", O.adam(0.05), False),
        ("cs_adam_noclean",
         O.countsketch_adam(0.05, policy=POL, hparams=HP), True),
        ("cs_adam_clean",
         O.countsketch_adam(0.05, policy=POL, hparams=HP, cleaning=clean),
         True),
        ("adagrad_dense", O.adagrad(0.5), False),
        ("cs_adagrad_noclean",
         O.countsketch_adagrad(0.5, policy=POL, hparams=HP), True),
        ("cs_adagrad_clean",
         O.countsketch_adagrad(0.5, policy=POL, hparams=HP,
                               cleaning=CleaningSchedule(alpha=0.5,
                                                         every=125)), True),
    ]:
        out[name] = _train(opt, steps, batch_fn, n_classes, d,
                           track_v_error=track)
    save_result("cleaning", out)
    return {k: round(v["accuracy"], 4) for k, v in out.items()}


if __name__ == "__main__":
    print(run())
