"""Benchmark aggregator — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full pass
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-speed pass
    PYTHONPATH=src python -m benchmarks.run --only small_lm,roofline

Results: experiments/bench/<name>.json + a printed summary.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

BENCHES = [
    ("power_law", "Fig 1-2: power-law + drifting identities"),
    ("approx_error", "Fig 4: l2 error CS vs rank-1"),
    ("small_lm", "Tab 3/4/7: perplexity per optimizer"),
    ("cleaning", "Fig 5: CMS cleaning ablation"),
    ("memory_time", "Tab 5/6: aux bytes + step time"),
    ("extreme", "Tab 8: MACH extreme classification"),
    ("extreme_scale", "Tab 8 at scale: batch sweep to the memory wall"),
    ("ablations", "(ours) compression sweep / strict semantics / fold"),
    ("kernels", "(ours) sketch kernel micro + traffic model"),
    ("fused_store", "(ours) fused vs composed update_read steps/sec"),
    ("obs_overhead", "(ours) telemetry on/off steps/s A-B"),
    ("serving", "(ours) SLO traffic replay: dense vs count-min adaptation"),
    ("roofline", "(ours) dry-run roofline tables"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    failures = 0
    for name, desc in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n=== {name}: {desc} ===", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            summary = mod.run(quick=args.quick)
            print(f"[{name}] {summary}")
            print(f"[{name}] done in {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"[{name}] FAILED:\n{traceback.format_exc()[-2000:]}",
                  flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
