"""Paper Fig. 4: ℓ2 error of each compression scheme tracking the Adam
auxiliary variables of a training run.

Protocol: run dense Adam on the small LM; in parallel, feed the SAME
per-step linear updates into (a) a count-sketch tensor, (b) the NMF
rank-1 factorization, (c) the ℓ2 rank-1 (power-iteration SVD) — each
given ≈ the same parameter budget — and record ‖approx − exact‖₂ /
‖exact‖₂ over time, for the 1st (signed) and 2nd (non-negative) moment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result, small_lm_cfg, train_small_lm
from repro.core import lowrank, optimizers as O
from repro.core import sketch as cs


def run(quick: bool = False):
    steps = 150 if quick else 400
    cfg = small_lm_cfg()
    n, d = cfg.vocab, cfg.d_model

    # Two sketch budgets, as in the paper: the strict equal-params point
    # (rank-1 uses n + d; at d=128 that forces width ≈ 5 — the sketch's
    # whole-row granularity makes this budget degenerate) and the paper's
    # LM setting (5× compression of the n-row axis; the paper's own
    # Wikitext-103 comparison likewise "provid[es] the Count-Sketch with
    # more parameters", Tab 5).
    budget = n + d
    depth = 3
    width_eq = max(4, int(budget / (depth * d)))
    width_5x = max(8, n // (5 * depth))
    spec_m = cs.SketchSpec(depth=depth, width=width_5x, dim=d, signed=True, seed=1)
    spec_v = cs.SketchSpec(depth=depth, width=width_5x, dim=d, signed=False, seed=2)
    spec_m_eq = cs.SketchSpec(depth=depth, width=width_eq, dim=d, signed=True, seed=3)
    ids = jnp.arange(n, dtype=jnp.int32)

    state = {
        "S_m": cs.init(spec_m), "S_v": cs.init(spec_v),
        "S_m_eq": cs.init(spec_m_eq),
        "r1_m": lowrank.l2_rank1_init((n, d)),
        "nmf_r": jnp.zeros((n,)), "nmf_c": jnp.zeros((d,)),
        "m": jnp.zeros((n, d)), "v": jnp.zeros((n, d)),
    }
    errors = []
    b1, b2 = 0.9, 0.999

    def collect(i, grads, st):
        g = jnp.asarray(grads["tok_embed"]["table"])
        s = state
        # exact moments
        m_new = b1 * s["m"] + (1 - b1) * g
        v_new = b2 * s["v"] + (1 - b2) * g * g
        # count-sketch: linear update matches the EMA exactly in sketch space
        s["S_m"] = cs.decay(s["S_m"], b1)
        s["S_m"] = cs.update(spec_m, s["S_m"], ids, (1 - b1) * g)
        s["S_m_eq"] = cs.decay(s["S_m_eq"], b1)
        s["S_m_eq"] = cs.update(spec_m_eq, s["S_m_eq"], ids, (1 - b1) * g)
        s["S_v"] = cs.decay(s["S_v"], b2)
        s["S_v"] = cs.update(spec_v, s["S_v"], ids, (1 - b2) * g * g)
        # NMF rank-1 of v (non-negative only, as in the paper)
        g2 = jnp.square(g)
        s["nmf_r"] = b2 * s["nmf_r"] + (1 - b2) * jnp.mean(g2, axis=1)
        s["nmf_c"] = b2 * s["nmf_c"] + (1 - b2) * jnp.mean(g2, axis=0)
        # l2 rank-1 of m (power iteration)
        s["r1_m"] = lowrank.l2_rank1_step(s["r1_m"], m_new)

        m_cs = cs.query(spec_m, s["S_m"], ids)
        m_cs_eq = cs.query(spec_m_eq, s["S_m_eq"], ids)
        v_cs = cs.query(spec_v, s["S_v"], ids)
        v_nmf = lowrank.nmf_rank1_reconstruct(s["nmf_r"], s["nmf_c"])
        m_r1 = lowrank.l2_rank1_reconstruct(s["r1_m"])

        def rel(a, b):
            return float(jnp.linalg.norm(a - b) /
                         jnp.maximum(jnp.linalg.norm(b), 1e-9))

        s["m"], s["v"] = m_new, v_new
        return {"step": i,
                "m_cs": rel(m_cs, m_new), "m_cs_eq": rel(m_cs_eq, m_new),
                "m_rank1": rel(m_r1, m_new),
                "v_cs": rel(v_cs, v_new), "v_nmf": rel(v_nmf, v_new)}

    res = train_small_lm(O.adam(1e-3), cfg=cfg, steps=steps,
                         collect_aux=collect)
    errors = res["aux"]
    tail = errors[len(errors) // 2:]
    out = {
        "rank1_params_per_moment": budget,
        "sketch_shape_5x": list(spec_m.shape),
        "sketch_shape_equal_budget": list(spec_m_eq.shape),
        "final_m_cs_equal_budget": float(np.mean([e["m_cs_eq"] for e in errors[len(errors) // 2:]])),
        "series": errors,
        "final_m_cs": float(np.mean([e["m_cs"] for e in tail])),
        "final_m_rank1": float(np.mean([e["m_rank1"] for e in tail])),
        "final_v_cs": float(np.mean([e["v_cs"] for e in tail])),
        "final_v_nmf": float(np.mean([e["v_nmf"] for e in tail])),
    }
    save_result("approx_error", out)
    return out


if __name__ == "__main__":
    print(run())
