"""(ours) Fused vs composed store execution — steps/sec of the optimizer
hot path (DESIGN.md §14).

Protocol: one sketched (n, d) table under ``scale_by_adam`` (CS-MV: both
moments sketched, compression 5×), dense full-table gradients — the
embedding/softmax regime where the paper's 38% training-throughput claim
lives.  Each row times the jit'd optimizer update alone (state donated,
loss/backward excluded) so the fused-vs-composed axis is not washed out
by model compute:

  composed   backend=None — the chunked-scan fallback (3 codec calls +
             interleaved EMA math per chunk; bit-identical legacy path)
  xla        fused one-pass update_read per moment (hash once, host-
             cached dense addressing, no scan)
  tiled      the Pallas kernel (TPU only; 'interpret' is a correctness
             backend, far too slow to time honestly on CPU)

Shapes sweep the cache regimes: the fused one-shot wins while a moment's
working set fits LLC and loses to the cache-blocked scan beyond it (on
CPU); the TPU answer at scale is the tiled kernel (VMEM tiles +
overlapped DMA).  Results: experiments/bench/fused_store.json.

    PYTHONPATH=src python benchmarks/fused_store.py --quick
    PYTHONPATH=src python -m benchmarks.fused_store --pin   # committed JSON

``--pin`` (must be the launch flag, before jax initializes) pins the
process to one core and disables the XLA:CPU thread pool: wall time then
measures the WORK ratio, immune to co-tenant scheduler noise — the
protocol behind the committed experiments/bench/fused_store.json (this
container's free-running numbers swing ±2x between minutes).  Unpinned,
the fused path additionally gains parallelism headroom (the composed
scan serializes its chunks), but that is not stably measurable here.
"""
from __future__ import annotations

import os
import sys

if "--pin" in sys.argv:                      # before jax initializes
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false"
                               ).strip()
    try:
        os.sched_setaffinity(0, {0})
    except (AttributeError, OSError):        # non-Linux hosts
        pass

import argparse
import time

import jax
import jax.numpy as jnp

try:
    from benchmarks.common import save_result
except ImportError:  # run as a script: python benchmarks/fused_store.py
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import save_result
from repro.core import optimizers as O
from repro.core.stores import CountMinStore, CountSketchStore, StoreTree

# (n, d): vocab-16k/d-64 is the LM1B-scale embedding table; 32k×32 the
# hash-heavier thin-table regime; 64k×64 probes where the one-shot's
# temps outgrow the LLC (the fused win inverts there on CPU — the TPU
# answer at that scale is the tiled Pallas kernel).
SHAPES = ((16384, 64), (32768, 32), (65536, 64))
BACKENDS = (None, "xla") + (("tiled",)
                            if jax.default_backend() == "tpu" else ())


def _tree(backend):
    return StoreTree.select(
        m=CountSketchStore(compression=5.0, backend=backend),
        v=CountMinStore(compression=5.0, backend=backend),
        where=lambda p, s: True)


def _prepare(backend, n: int, d: int):
    opt = O.adam_from_stores(1e-3, _tree(backend))
    params = {"table": jax.random.normal(jax.random.PRNGKey(0), (n, d))}
    g = {"table": jax.random.normal(jax.random.PRNGKey(1), (n, d)) * 0.1}
    state = opt.init(params)
    step = jax.jit(lambda g, s: opt.update(g, s), donate_argnums=(1,))
    u, state = step(g, state)
    jax.block_until_ready(u)                     # compile + warm
    return [step, g, state]


def bench_shape(n: int, d: int, backends, steps: int, windows: int = 5):
    """{backend: (steps/sec wall, cpu ms/step)} — INTERLEAVED A/B
    windows with MIN-over-windows per backend: co-tenant interference
    only ever ADDS time, and interleaving exposes every backend to the
    same noise regime instead of penalizing whichever ran during a bad
    stretch (the protocol calibrated in EXPERIMENTS.md §FusedStore)."""
    runs = {be: _prepare(be, n, d) for be in backends}
    wall = {be: float("inf") for be in backends}
    cpu = {be: float("inf") for be in backends}
    for _ in range(windows):
        for be in backends:
            step, g, state = runs[be]
            c0, t0 = time.process_time(), time.perf_counter()
            for _ in range(steps):
                u, state = step(g, state)
            jax.block_until_ready(u)
            wall[be] = min(wall[be], (time.perf_counter() - t0) / steps)
            cpu[be] = min(cpu[be], (time.process_time() - c0) / steps)
            runs[be][2] = state
    return {be: (1.0 / wall[be], cpu[be] * 1000.0) for be in backends}


def run(quick: bool = False, shapes=SHAPES, backends=BACKENDS):
    steps = 5 if quick else 10
    out = {}
    for n, d in shapes:
        res = bench_shape(n, d, backends, steps,
                          windows=3 if quick else 5)
        row = {(be or "composed"): round(res[be][0], 3) for be in backends}
        cpu_ms = {(be or "composed"): round(res[be][1], 2)
                  for be in backends}
        base = row["composed"]
        fused = {k: v for k, v in row.items() if k != "composed"}
        best = max(fused, key=fused.get)
        out[f"{n}x{d}"] = {
            "n": n, "dim": d, "steps_per_s": row, "cpu_ms_per_step": cpu_ms,
            "best_fused_backend": best,
            "speedup_best_fused": round(fused[best] / base, 3),
            "cpu_speedup_best_fused": round(cpu_ms["composed"]
                                            / cpu_ms[best], 3),
        }
    best = max(out.values(), key=lambda r: r["speedup_best_fused"])
    summary = {
        "protocol": "scale_by_adam on one sketched table, optimizer "
                    "update only, state donated, compression 5x; "
                    "interleaved A/B windows, min-over-windows timing "
                    "(wall + process-CPU)",
        "pinned": "--pin" in sys.argv,
        "device": jax.default_backend(),
        "steps_timed": steps,
        "rows": out,
        "max_speedup": best["speedup_best_fused"],
        "max_speedup_at": f"{best['n']}x{best['dim']}",
    }
    save_result("fused_store", summary)
    return {k: (v["steps_per_s"], f"{v['speedup_best_fused']}x")
            for k, v in out.items()}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--pin", action="store_true",
                    help="pin to one core + single-threaded XLA (stable "
                         "work-ratio protocol; handled before jax init)")
    ap.add_argument("--shapes", default="",
                    help="comma-separated NxD overrides, e.g. 16384x64")
    a = ap.parse_args()
    shapes = SHAPES
    if a.shapes:
        shapes = tuple(tuple(int(x) for x in s.split("x"))
                       for s in a.shapes.split(","))
    print(run(quick=a.quick, shapes=shapes))
