"""Paper Tables 5/6: optimizer-state memory + step time per scheme.

Protocol: a mid-size LM (vocab 16k, d=256) so the embedding/softmax aux
state dominates, as in Wikitext-103/LM1B.  Reports bytes of optimizer
state, steps/s, and the paper-style "Size" ratio vs dense Adam.
"""
from __future__ import annotations

from benchmarks.common import save_result, small_lm_cfg, strip_arrays, \
    train_small_lm
from repro.core import lowrank, optimizers as O
from repro.core.partition import SketchPolicy

POL = SketchPolicy(min_rows=512)
HP = O.SketchHParams(compression=5.0, width_multiple=16)


def run(quick: bool = False):
    steps = 30 if quick else 80
    cfg = small_lm_cfg(vocab=16384, d_model=256, n_layers=2)
    kw = dict(cfg=cfg, steps=steps, batch=4, seq=64)
    out = {}
    for name, opt in [
        ("adam", O.adam(1e-3)),
        ("cs_mv", O.countsketch_adam(1e-3, policy=POL, hparams=HP)),
        ("cs_v", O.countsketch_adam(1e-3, policy=POL, hparams=HP,
                                    sketch_first_moment=False)),
        ("cs_rmsprop_b1_0", O.countsketch_rmsprop(1e-3, policy=POL,
                                                  hparams=HP)),
        ("lr_nmf_v", lowrank.nmf_rank1_adam(1e-3, policy=POL)),
        ("adagrad", O.adagrad(0.1)),
        ("cs_adagrad", O.countsketch_adagrad(0.1, policy=POL, hparams=HP)),
    ]:
        out[name] = strip_arrays(train_small_lm(opt, **kw))
    base = out["adam"]["opt_state_bytes"]
    table = {k: {"bytes": v["opt_state_bytes"],
                 "size_ratio": round(v["opt_state_bytes"] / base, 3),
                 "steps_per_s": round(v["steps_per_s"], 2),
                 "final_loss": round(v["final_loss"], 3)}
             for k, v in out.items()}
    save_result("memory_time", {"detail": out, "table": table})
    return table


if __name__ == "__main__":
    print(run())
