"""Paper Tables 5/6: optimizer-state memory + step time per aux STORE.

Protocol: a mid-size LM (vocab 16k, d=256) so the embedding/softmax aux
state dominates, as in Wikitext-103/LM1B.  Reports bytes of optimizer
state, steps/s, and the paper-style "Size" ratio vs dense Adam.

The memory/accuracy axis is the ``--store`` axis (DESIGN.md §12): the
same ``scale_by_adam`` rule runs over a ``DenseStore``, a
``CountSketchStore``/``CountMinStore`` pair (the paper's CS-MV), or a
``Rank1Store`` (LR-NMF-V) — one row per store kind, replacing the old
per-scheme policy-flag plumbing.  Every row records **per-store
predicted vs measured** aux bytes (the per-store ``bytes()`` codec
method summed over the resolved StoreTree) — the predicted/measured gap
is the store accounting's calibration check (EXPERIMENTS.md §Planner).
With ``--aux-budget`` the planner itself drives extra rows: each budget
is solved into a per-leaf plan, whose ``StoreTree`` then executes.

    PYTHONPATH=src python -m benchmarks.memory_time --quick \
        --store dense,sketch,rank1 --aux-budget floor,0.35x,1.0x
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import save_result, small_lm_cfg, strip_arrays, \
    train_small_lm
from repro.core import optimizers as O
from repro.core.partition import SketchPolicy, leaf_paths
from repro.core.stores import (CountMinStore, CountSketchStore, DenseStore,
                               Rank1Store, StoreTree)
from repro.plan import accounting, parse_budget, plan_for_params, \
    min_budget_bytes

POL = SketchPolicy(min_rows=512)

STORE_KINDS = ("dense", "sketch", "rank1")


def store_tree_for(kind: str) -> StoreTree:
    """The StoreTree one ``--store`` row executes: sketched/rank-1 aux on
    the policy-selected tables, dense elsewhere."""
    if kind == "dense":
        return StoreTree()
    if kind == "sketch":
        return StoreTree.select(
            m=CountSketchStore(compression=5.0, width_multiple=16),
            v=CountMinStore(compression=5.0, width_multiple=16),
            where=POL)
    if kind == "rank1":
        return StoreTree.select(m=DenseStore(), v=Rank1Store(), where=POL)
    raise ValueError(f"unknown store kind {kind!r} (use {STORE_KINDS})")


def predicted_aux_bytes(stores: StoreTree, ps) -> int:
    """Sum of the per-store ``bytes()`` predictions over the resolved
    tree — must equal ``accounting.measure_aux_bytes`` of the real state."""
    total = 0
    for path, leaf in leaf_paths(ps):
        m, v = stores.resolve(path, tuple(leaf.shape), leaf.dtype)
        total += (m.bytes() if m is not None else 0) + v.bytes()
    return total


def _entry(res, predicted):
    measured = accounting.measure_aux_bytes(res["opt_state"])
    out = strip_arrays(res)
    out["predicted_aux_bytes"] = int(predicted)
    out["measured_aux_bytes"] = int(measured)
    out["predicted_vs_measured_gap"] = (
        abs(predicted - measured) / measured if measured else 0.0)
    return out


def run(quick: bool = False, store_kinds=STORE_KINDS, aux_budgets=()):
    steps = 30 if quick else 80
    cfg = small_lm_cfg(vocab=16384, d_model=256, n_layers=2)
    kw = dict(cfg=cfg, steps=steps, batch=4, seq=64)
    from repro.models import transformer as tf
    ps = jax.eval_shape(lambda k: tf.init(k, cfg), jax.random.PRNGKey(0))

    out = {}
    # --- the --store axis: one row per store kind, same Adam rule
    for kind in store_kinds:
        stores = store_tree_for(kind)
        opt = O.adam_from_stores(1e-3, stores)
        e = _entry(train_small_lm(opt, **kw),
                   predicted_aux_bytes(stores, ps))
        e["store"] = kind
        out[f"store@{kind}"] = e

    # --- planner-driven budget axis (the solved per-leaf StoreTree)
    dense = accounting.dense_budget_bytes(ps)
    floor = min_budget_bytes(ps, width_multiple=16, min_rows=512)
    for b in aux_budgets:
        budget = parse_budget(b, dense_bytes=dense, floor_bytes=floor)
        plan = plan_for_params(ps, budget, width_multiple=16, min_rows=512)
        res = train_small_lm(plan.make_optimizer(1e-3), **kw)
        e = _entry(res, plan.predicted_aux_bytes)
        e.update(aux_budget=b, budget_bytes=int(budget),
                 plan_modes=plan.n_by_mode())
        out[f"plan@{b}"] = e

    if not out:
        raise ValueError("nothing to run: pass at least one --store kind "
                         "or --aux-budget")
    # paper-style "Size" ratio is ALWAYS vs dense Adam, whether or not a
    # dense row was requested (dense aux + the 4 B step scalar)
    base_bytes = dense + 4
    table = {k: {"bytes": v["opt_state_bytes"],
                 "predicted_aux_bytes": v["predicted_aux_bytes"],
                 "measured_aux_bytes": v["measured_aux_bytes"],
                 "size_ratio": round(v["opt_state_bytes"] / base_bytes, 3),
                 "steps_per_s": round(v["steps_per_s"], 2),
                 "final_loss": round(v["final_loss"], 3)}
             for k, v in out.items()}
    save_result("memory_time", {"detail": out, "table": table,
                                "dense_aux_bytes": int(dense),
                                "floor_aux_bytes": int(floor)})
    return table


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--store", default="dense,sketch,rank1",
                    help="comma-separated store kinds (dense|sketch|rank1) "
                         "— one benchmark row per kind")
    ap.add_argument("--aux-budget", default="",
                    help="comma-separated budgets driving the planner axis "
                         "('floor', fractions of dense like '0.35x', bytes)")
    a = ap.parse_args()
    kinds = [s for s in a.store.split(",") if s]
    budgets = [b for b in a.aux_budget.split(",") if b]
    print(run(quick=a.quick, store_kinds=kinds, aux_budgets=budgets))
