"""Paper Tables 5/6: optimizer-state memory + step time per scheme.

Protocol: a mid-size LM (vocab 16k, d=256) so the embedding/softmax aux
state dominates, as in Wikitext-103/LM1B.  Reports bytes of optimizer
state, steps/s, and the paper-style "Size" ratio vs dense Adam.

Every scheme also records the **planner-predicted vs measured** aux bytes
(``repro.plan.accounting``) — the predicted/measured gap is the planner's
calibration check (EXPERIMENTS.md §Planner).  With ``--aux-budget`` the
memory/accuracy trade-off axis is driven by the planner itself: each
budget (a fraction of the dense-Adam aux cost, e.g. ``0.35x``, or
``floor``) is solved into a per-leaf plan and trained, replacing the old
hand compression sweep.

    PYTHONPATH=src python benchmarks/memory_time.py --quick \
        --aux-budget floor,0.35x,0.6x,1.0x
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import save_result, small_lm_cfg, strip_arrays, \
    train_small_lm
from repro.core import lowrank, optimizers as O
from repro.core.partition import SketchPolicy, nothing_policy
from repro.models import transformer as tf
from repro.plan import accounting, parse_budget, plan_for_params, \
    min_budget_bytes

POL = SketchPolicy(min_rows=512)
HP = O.SketchHParams(compression=5.0, width_multiple=16)


def _entry(res, predicted):
    measured = accounting.measure_aux_bytes(res["opt_state"])
    out = strip_arrays(res)
    out["predicted_aux_bytes"] = int(predicted)
    out["measured_aux_bytes"] = int(measured)
    out["predicted_vs_measured_gap"] = (
        abs(predicted - measured) / measured if measured else 0.0)
    return out


def run(quick: bool = False, aux_budgets=()):
    steps = 30 if quick else 80
    cfg = small_lm_cfg(vocab=16384, d_model=256, n_layers=2)
    kw = dict(cfg=cfg, steps=steps, batch=4, seq=64)
    ps = jax.eval_shape(lambda k: tf.init(k, cfg), jax.random.PRNGKey(0))

    def predict(policy=nothing_policy, rank1_policy=nothing_policy,
                track_first=True, sketch_first=True):
        return accounting.predict_policy_bytes(
            ps, policy=policy, rank1_policy=rank1_policy, hparams=HP,
            track_first_moment=track_first, sketch_first_moment=sketch_first)

    out = {}
    for name, opt, predicted in [
        ("adam", O.adam(1e-3), predict()),
        ("cs_mv", O.countsketch_adam(1e-3, policy=POL, hparams=HP),
         predict(policy=POL)),
        ("cs_v", O.countsketch_adam(1e-3, policy=POL, hparams=HP,
                                    sketch_first_moment=False),
         predict(policy=POL, sketch_first=False)),
        ("cs_rmsprop_b1_0", O.countsketch_rmsprop(1e-3, policy=POL,
                                                  hparams=HP),
         predict(policy=POL, track_first=False, sketch_first=False)),
        ("lr_nmf_v", lowrank.nmf_rank1_adam(1e-3, policy=POL),
         predict(rank1_policy=POL)),
        ("adagrad", O.adagrad(0.1), predict(track_first=False)),
        ("cs_adagrad", O.countsketch_adagrad(0.1, policy=POL, hparams=HP),
         predict(policy=POL, track_first=False)),
    ]:
        out[name] = _entry(train_small_lm(opt, **kw), predicted)

    # --- planner-driven budget axis (replaces the hand compression sweep)
    dense = accounting.dense_budget_bytes(ps)
    floor = min_budget_bytes(ps, width_multiple=16, min_rows=512)
    for b in aux_budgets:
        budget = parse_budget(b, dense_bytes=dense, floor_bytes=floor)
        plan = plan_for_params(ps, budget, width_multiple=16, min_rows=512)
        res = train_small_lm(plan.make_optimizer(1e-3), **kw)
        e = _entry(res, plan.predicted_aux_bytes)
        e.update(aux_budget=b, budget_bytes=int(budget),
                 plan_modes=plan.n_by_mode())
        out[f"plan@{b}"] = e

    base = out["adam"]["opt_state_bytes"]
    table = {k: {"bytes": v["opt_state_bytes"],
                 "predicted_aux_bytes": v["predicted_aux_bytes"],
                 "measured_aux_bytes": v["measured_aux_bytes"],
                 "size_ratio": round(v["opt_state_bytes"] / base, 3),
                 "steps_per_s": round(v["steps_per_s"], 2),
                 "final_loss": round(v["final_loss"], 3)}
             for k, v in out.items()}
    save_result("memory_time", {"detail": out, "table": table,
                                "dense_aux_bytes": int(dense),
                                "floor_aux_bytes": int(floor)})
    return table


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--aux-budget", default="",
                    help="comma-separated budgets driving the planner axis "
                         "('floor', fractions of dense like '0.35x', bytes)")
    a = ap.parse_args()
    budgets = [b for b in a.aux_budget.split(",") if b]
    print(run(quick=a.quick, aux_budgets=budgets))
