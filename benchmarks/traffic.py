import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()

"""Dense vs sketched all-reduce traffic for the DP sparse-embedding step.

Mirrors the paper's systems claim at CPU scale (DESIGN.md §13): for a
data-parallel (ids, rows) embedding gradient, all-reducing the
(depth, width, dim) count sketches moves a fraction of the bytes of
all-gathering the (k, d) rows.  Both paths are COMPILED against an
8-device forced host platform and the collective bytes are read from the
optimized post-SPMD HLO (launch/analysis.parse_collectives) — measured,
not just predicted; the prediction (`sketched_reduce.traffic_ratio`, the
bytes-based accounting) is recorded alongside for regression.

Each record also carries the MODEL-PARALLEL sketch rows (DESIGN.md §17):

  * ``routing_bytes`` — the shard-axis routing psum, measured from a
    shard-ONLY compile (state sharded 8-way over 'model', no dp axis):
    the routing psum is then the step's only collective, so the HLO
    collective bytes ARE the routing traffic.  ``routing_predicted`` is
    ``sketched_reduce.routing_bytes`` over the four query groups the
    step routes (g, v, g², m).
  * ``dp_sharded_bytes`` — the composed dp×shard step (2×4 mesh): the
    PR 4 gradient-sketch psum now moves width SLABS, so its payload is
    1/shards of the 1D dp sketched payload, plus the routing psum.

    PYTHONPATH=src python benchmarks/traffic.py            # full sweep
    PYTHONPATH=src python benchmarks/traffic.py --quick

Results land in experiments/bench/traffic.json; the table in
EXPERIMENTS.md §Traffic is generated from them.
"""
import argparse

import jax
import jax.numpy as jnp

try:
    from benchmarks.common import save_result
except ImportError:     # run as `python benchmarks/traffic.py`
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import save_result

from repro.core import optimizers as O
from repro.core.optimizers import SketchHParams
from repro.distributed import sharding as shd
from repro.distributed import sketched_reduce as sr
from repro.launch import analysis
from repro.train.steps import make_sparse_embedding_step

N_DEV = 8
SHARDS = 4          # shard count for the composed dp(2) × shard(4) mesh


def _collective_bytes(fn, args) -> dict:
    """Compile ``fn(*args)`` and read per-kind collective bytes from the
    optimized HLO."""
    compiled = jax.jit(fn).lower(*args).compile()
    cols = analysis.parse_collectives(compiled.as_text(), N_DEV)
    return {k: v["bytes"] for k, v in cols.items() if v["count"]}


def dense_dp_step(mesh, n_rows, dim, hp):
    """The baseline DP path: all-gather the (k, d) gradient rows + ids,
    run the single-device sparse CS-Adam update on the concatenated
    batch.  Same optimizer, dense collective."""
    opt = O.sparse_rows_adam(1e-2, shape=(n_rows, dim), hparams=hp)

    def inner(table, state, ids, rows):
        gids = jax.lax.all_gather(ids, "data").reshape(-1)
        grows = jax.lax.all_gather(rows, "data").reshape(-1, dim)
        updates, state = opt.update({"ids": gids, "rows": grows}, state)
        return O.apply_sparse_updates(table, updates), state

    return shd.dp_sparse_wrap(inner, mesh=mesh), opt


def run(n_rows: int, dim: int, batch: int, compressions) -> dict:
    mesh = shd.make_mesh_compat((N_DEV,), ("data",))
    rows_arr = jnp.zeros((batch, dim), jnp.float32)
    ids_arr = jnp.zeros((batch,), jnp.int32)
    table = jnp.zeros((n_rows, dim), jnp.float32)

    records = []
    for compression in compressions:
        hp = SketchHParams(compression=compression)
        # sketched path
        _, dp_step, dp_opt = make_sparse_embedding_step(
            n_rows, dim, lr=1e-2, hparams=hp, dp_axis="data", mesh=mesh)
        sk_cols = _collective_bytes(
            dp_step, (table, dp_opt.init(), ids_arr, rows_arr))
        # dense path (same optimizer semantics, rows over the wire)
        dn_step, dn_opt = dense_dp_step(mesh, n_rows, dim, hp)
        dn_cols = _collective_bytes(
            dn_step, (table, dn_opt.init(), ids_arr, rows_arr))

        # sharded-sketch routing row (DESIGN.md §17): shard-only mesh —
        # no dp axis, so the shard-axis routing psum is the step's ONLY
        # collective and the measured HLO bytes are pure routing traffic
        mesh_sh = shd.make_mesh_compat((N_DEV,), ("model",))
        _, sh_step, sh_opt = make_sparse_embedding_step(
            n_rows, dim, lr=1e-2, hparams=hp, mesh=mesh_sh,
            sketch_shards=N_DEV)
        rt_cols = _collective_bytes(
            sh_step, (table, sh_opt.init(), ids_arr, rows_arr))
        # composed dp × shard: the PR 4 psum payload shrinks to slabs
        mesh_2d = shd.make_mesh_compat((N_DEV // SHARDS, SHARDS),
                                       ("data", "model"))
        _, ds_step, ds_opt = make_sparse_embedding_step(
            n_rows, dim, lr=1e-2, hparams=hp, dp_axis="data", mesh=mesh_2d,
            sketch_shards=SHARDS)
        ds_cols = _collective_bytes(
            ds_step, (table, ds_opt.init(), ids_arr, rows_arr))

        sk_bytes = sum(sk_cols.values())
        dn_bytes = sum(dn_cols.values())
        rt_bytes = sum(rt_cols.values())
        ds_bytes = sum(ds_cols.values())
        spec_m = hp.spec("sparse_embedding", (n_rows, dim), signed=True)
        spec_v = hp.spec("sparse_embedding", (n_rows, dim), signed=False)
        predicted = sr.traffic_ratio(spec_m, batch,
                                     extra_specs=(spec_v,))
        # the sharded step routes four (depth, k, dim) query groups per
        # step: ghat, v_old, g²hat, m_old (sketched_reduce.sharded_adam_rows)
        rt_pred = sr.routing_bytes(batch, spec_m, spec_v, spec_v, spec_m)
        rec = {
            "compression": compression,
            "rows": n_rows, "dim": dim, "batch": batch,
            "dense_bytes": dn_bytes, "dense_collectives": dn_cols,
            "sketched_bytes": sk_bytes, "sketched_collectives": sk_cols,
            "measured_ratio": dn_bytes / sk_bytes if sk_bytes else None,
            "predicted_ratio": predicted,
            "sketch_shards": N_DEV,
            "routing_bytes": rt_bytes, "routing_collectives": rt_cols,
            "routing_predicted": rt_pred,
            "dp_sharded_shards": SHARDS,
            "dp_sharded_bytes": ds_bytes, "dp_sharded_collectives": ds_cols,
        }
        records.append(rec)
        print(f"compression={compression:6.1f}x  dense={dn_bytes:>12,} B  "
              f"sketched={sk_bytes:>12,} B  "
              f"measured {rec['measured_ratio']:.1f}x  "
              f"predicted {predicted:.1f}x", flush=True)
        print(f"{'':>18s}  routing(x{N_DEV})={rt_bytes:>10,} B "
              f"(pred {rt_pred:,})  dp×shard(2x{SHARDS})={ds_bytes:>12,} B",
              flush=True)
    return {"devices": N_DEV, "records": records}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--batch", type=int, default=100_000,
                    help="global touched rows per step (default k == n: "
                         "the full-softmax regime the paper compresses)")
    ap.add_argument("--compressions", default="5,10,20,40,100",
                    help="paper compressions: 5x (LM1B aux memory) up to "
                         "100x (49.5M-class Amazon)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        args.rows = args.batch = 16_384
        args.compressions = "10,40"
    comps = [float(c) for c in args.compressions.split(",")]
    payload = run(args.rows, args.dim, args.batch, comps)
    path = save_result("traffic", payload)
    print(f"[traffic] wrote {path}")
    # with both moment sketches riding the collective the byte ratio is
    # ~compression/2: the 5x gate is met from compression ≳ 10 up
    best = max(r["measured_ratio"] for r in payload["records"])
    print(f"[traffic] best measured reduction: {best:.1f}x")
    return 0 if best >= 5.0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
