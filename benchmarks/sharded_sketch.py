import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()

"""Model-parallel sketches: train a table whose TOTAL sketch bytes exceed
the per-device aux budget (DESIGN.md §17).

The acceptance demo for sketch sharding, end to end on a forced 8-device
host platform:

  1. **budget failure** — planning the table UNSHARDED under the
     per-device budget raises ``InfeasibleBudgetError`` (the cheapest
     CS-MV sketch pair already overflows one device);
  2. **sharded plan** — the same budget with ``shards=8`` plans: each
     device holds one width slab, so the per-device bytes fit while the
     TOTAL sketch bytes exceed the budget (the state could not live on
     any single device);
  3. **training** — the planned store tree trains the sparse-embedding
     regression for a few dozen steps on the 8-way 'model' mesh
     (``make_sparse_embedding_step(sketch_shards=8)``), loss decreasing,
     and the per-shard occupancy gauges come back balanced.

    PYTHONPATH=src python benchmarks/sharded_sketch.py
    PYTHONPATH=src python benchmarks/sharded_sketch.py --quick

Results land in experiments/bench/sharded_sketch.json; the table in
EXPERIMENTS.md §ShardedSketch is generated from them.  The routing-
traffic counterpart rows live in benchmarks/traffic.py.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import save_result
except ImportError:     # run as `python benchmarks/sharded_sketch.py`
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import save_result

from repro.distributed import sharding as shd
from repro.plan import allocator
from repro.plan.cli import plan_for_tables
from repro.train.steps import make_sparse_embedding_step, \
    sparse_embedding_stores

N_DEV = 8
PATH = "tok_embed/table"


def run(n_rows: int, dim: int, batch: int, steps: int, budget: int,
        shards: int, layout: str, lr: float, alpha: float,
        seed: int = 0) -> dict:
    shapes = {PATH: (n_rows, dim)}
    ps = {PATH: jax.ShapeDtypeStruct((n_rows, dim), jnp.float32)}
    floor_1 = allocator.min_budget_bytes(ps)
    floor_n = allocator.min_budget_bytes(ps, shards=shards)

    # 1. unsharded: the budget failure the per-device budget forces
    try:
        plan_for_tables(shapes, budget, optimizer="cs_adam")
        unsharded = {"planned": True}      # would invalidate the demo
    except allocator.InfeasibleBudgetError as e:
        unsharded = {"planned": False, "error": type(e).__name__,
                     "message": str(e)}
    print(f"[sharded_sketch] unsharded floor {floor_1:,} B vs budget "
          f"{budget:,} B -> "
          + ("PLANNED (demo void!)" if unsharded["planned"]
             else unsharded["error"]), flush=True)

    # 2. sharded: same budget, per-device accounting
    plan = plan_for_tables(shapes, budget, optimizer="cs_adam",
                           shards=shards, shard_layout=layout)
    leaf = plan.leaf(PATH)
    total = plan.predicted_aux_bytes
    per_dev = plan.predicted_aux_bytes_per_device
    print(f"[sharded_sketch] shards={shards}({layout}) width={leaf.width} "
          f"per-device {per_dev:,} B <= {budget:,} B < total {total:,} B",
          flush=True)

    # 3. train the sparse-embedding regression on the 8-way 'model' mesh
    tree = plan.store_tree()
    mesh = shd.make_mesh_compat((shards,), ("model",))
    init_fn, step_fn, opt = make_sparse_embedding_step(
        n_rows, dim, lr=lr, stores=tree, path=PATH, mesh=mesh,
        sketch_shards=shards, shard_layout=layout)
    scale = 1.0 / np.sqrt(dim)
    table = init_fn(jax.random.PRNGKey(seed))
    target = jax.random.normal(jax.random.PRNGKey(seed + 1),
                               (n_rows, dim), jnp.float32) * scale
    state = opt.init()
    step_c = jax.jit(step_fn)
    rng = np.random.default_rng(seed)
    losses = []
    for _ in range(steps):
        ids = jnp.asarray((rng.zipf(alpha, size=batch) - 1) % n_rows,
                          jnp.int32)
        rows = table[ids] - target[ids]        # d/dtable ½‖table−target‖²
        losses.append(float(jnp.mean(jnp.square(rows))))
        table, state = step_c(table, state, ids, rows)
    m_st, v_st = sparse_embedding_stores(
        n_rows, dim, stores=tree, path=PATH, sketch_shards=shards,
        shard_layout=layout)
    v_stats = {k: float(v) for k, v in v_st.stats(state["v"]).items()}
    print(f"[sharded_sketch] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({steps} steps)  shard occ "
          f"{v_stats.get('shard_occ_min', 0.0):.3f} .. "
          f"{v_stats.get('shard_occ_max', 0.0):.3f}", flush=True)

    return {
        "devices": N_DEV, "rows": n_rows, "dim": dim, "batch": batch,
        "steps": steps, "alpha": alpha, "lr": lr,
        "budget_bytes": budget,
        "unsharded_floor_bytes": floor_1,
        "sharded_floor_bytes_per_device": floor_n,
        "unsharded": unsharded,
        "sharded_plan": {
            "shards": shards, "layout": layout, "width": leaf.width,
            "total_bytes": total, "per_device_bytes": per_dev,
            "exceeds_single_device_budget": total > budget,
        },
        "train": {
            "first_loss": losses[0], "final_loss": losses[-1],
            "losses": losses[:: max(1, len(losses) // 50)],
            "v_stats": v_stats,
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8_192)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--budget", type=int, default=256 * 2**10,
                    help="per-DEVICE aux budget in bytes; keep it below "
                         "the unsharded CS-MV floor (2×3×256×dim×4 B) so "
                         "the unsharded plan fails")
    ap.add_argument("--shards", type=int, default=N_DEV)
    ap.add_argument("--layout", default="width", choices=("width", "hash"))
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--alpha", type=float, default=1.3)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.batch, args.steps = 20_000, 2_048, 20
    payload = run(args.rows, args.dim, args.batch, args.steps, args.budget,
                  args.shards, args.layout, args.lr, args.alpha)
    path = save_result("sharded_sketch", payload)
    print(f"[sharded_sketch] wrote {path}")
    ok = (not payload["unsharded"]["planned"]
          and payload["sharded_plan"]["exceeds_single_device_budget"]
          and payload["sharded_plan"]["per_device_bytes"] <= args.budget
          and payload["train"]["final_loss"] < payload["train"]["first_loss"])
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
