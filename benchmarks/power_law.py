"""Paper Fig. 1-2: gradients and auxiliary variables follow a power law
whose top-k identities drift over training.

Protocol: train the small LM with dense Adam; every 25 steps record, for
the embedding-table gradient and both Adam moments, the 50%-mass
threshold (fraction of entries holding half the total |value| mass —
0.5 for uniform, ≪ 0.5 for power law) and the top-100 row identities.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, strip_arrays, train_small_lm
from repro.core import optimizers as O


def mass_threshold(x: np.ndarray, frac: float = 0.5) -> float:
    """Fraction of entries that carry ``frac`` of the total |x| mass."""
    a = np.sort(np.abs(x).ravel())[::-1]
    total = a.sum()
    if total == 0:
        return 0.5
    k = int(np.searchsorted(np.cumsum(a), frac * total)) + 1
    return k / a.size


def run(quick: bool = False):
    steps = 150 if quick else 400

    snapshots = []

    def collect(i, grads, st):
        g = np.asarray(grads["tok_embed"]["table"])
        m = np.asarray(st["m"]["tok_embed"]["table"])
        v = np.asarray(st["v"]["tok_embed"]["table"])
        row_mass = np.abs(m).sum(axis=1)
        return {
            "step": i,
            "grad_thresh": mass_threshold(g),
            "m_thresh": mass_threshold(m),
            "v_thresh": mass_threshold(v),
            "top100": np.argsort(-row_mass)[:100].tolist(),
        }

    res = train_small_lm(O.adam(1e-3), steps=steps, collect_aux=collect)
    snaps = res["aux"]
    # identity drift: overlap of top-100 sets between early and late
    early, late = set(snaps[1]["top100"]), set(snaps[-1]["top100"])
    out = {
        "thresholds": [{k: s[k] for k in
                        ("step", "grad_thresh", "m_thresh", "v_thresh")}
                       for s in snaps],
        "avg_m_thresh": float(np.mean([s["m_thresh"] for s in snaps[1:]])),
        "avg_v_thresh": float(np.mean([s["v_thresh"] for s in snaps[1:]])),
        "top100_overlap_early_late": len(early & late) / 100.0,
        "powerlaw_confirmed": bool(
            np.mean([s["m_thresh"] for s in snaps[1:]]) < 0.2),
        "train": strip_arrays(res),
    }
    save_result("power_law", out)
    return out


if __name__ == "__main__":
    print(run())
